//! Quickstart: train an optimized full-CP classifier, predict with
//! coverage guarantees, and see the paper's speedup first hand.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use exact_cp::cp::classifier::FullCp;
use exact_cp::data::{make_classification, ClassificationSpec, Rng};
use exact_cp::measures::knn::{KnnOptimized, KnnStandard};

fn main() {
    // 1. A binary classification workload (the paper's §7 setup).
    let all = make_classification(
        &ClassificationSpec {
            n_samples: 2_100,
            n_features: 30,
            ..Default::default()
        },
        42,
    );
    let mut rng = Rng::seed_from(7);
    let (train, test) = all.split(2_000, &mut rng);

    // 2. Full CP with the optimized k-NN measure: O(n^2) train,
    //    O(n) per prediction (paper §3.1).
    let t0 = std::time::Instant::now();
    let cp = FullCp::train(KnnOptimized::new(15, false), &train);
    println!("trained optimized k-NN CP on n=2000 in {:?}", t0.elapsed());

    // 3. Set predictions with a 90% coverage guarantee.
    let eps = 0.1;
    let mut covered = 0;
    let mut set_sizes = 0usize;
    let t0 = std::time::Instant::now();
    for i in 0..test.n() {
        let set = cp.predict_set(test.row(i), eps);
        covered += set.contains(&test.y[i]) as usize;
        set_sizes += set.len();
        if i < 5 {
            let f = cp.forced(test.row(i));
            println!(
                "  x[{i}]: set={set:?} true={} forced={} cred={:.2} conf={:.2}",
                test.y[i], f.label, f.credibility, f.confidence
            );
        }
    }
    let per_pred = t0.elapsed() / test.n() as u32;
    println!(
        "eps={eps}: coverage {}/{} (guarantee >= {:.0}%), avg set size {:.2}, \
         {per_pred:?}/prediction",
        covered,
        test.n(),
        (1.0 - eps) * 100.0,
        set_sizes as f64 / test.n() as f64,
    );

    // 4. The point of the paper: the standard measure computes the SAME
    //    p-values at ~n times the cost. Check on a subset.
    let small = {
        let mut rng = Rng::seed_from(8);
        let (s, _) = train.split(300, &mut rng);
        s
    };
    let cp_std = FullCp::train(KnnStandard::new(15, false), &small);
    let cp_opt = FullCp::train(KnnOptimized::new(15, false), &small);
    let x = test.row(0);
    let t0 = std::time::Instant::now();
    let p_std = cp_std.p_values(x);
    let t_std = t0.elapsed();
    let t0 = std::time::Instant::now();
    let p_opt = cp_opt.p_values(x);
    let t_opt = t0.elapsed();
    assert_eq!(p_std, p_opt, "exactness: identical p-values");
    println!(
        "exactness check at n=300: p-values identical ({p_opt:?}); \
         standard {t_std:?} vs optimized {t_opt:?} \
         ({:.0}x speedup on one prediction)",
        t_std.as_secs_f64() / t_opt.as_secs_f64().max(1e-9)
    );
}
