//! END-TO-END driver: proves all three layers compose on a real small
//! workload (recorded in EXPERIMENTS.md §End-to-end).
//!
//!   L1/L2 (build time): `make artifacts` lowered the Pallas distance /
//!         KDE kernels through JAX to HLO text;
//!   runtime: this binary loads them over the PJRT C API and routes the
//!         optimized measures' distance hot-spot through them;
//!   L3:   the coordinator trains two deployments, starts the TCP
//!         server with dynamic batching, and this driver plays client:
//!         concurrent batched prediction requests plus online
//!         learn/unlearn, reporting latency and throughput.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_pipeline
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use exact_cp::config::{MeasureConfig, MeasureKind, ServeConfig};
use exact_cp::coordinator::factory::select_engine;
use exact_cp::coordinator::server::{serve, Server};
use exact_cp::coordinator::state::{Deployment, Registry};
use exact_cp::cp::metrics::coverage;
use exact_cp::data::{make_classification, ClassificationSpec, Rng};
use exact_cp::util::json::Json;

const N_TRAIN: usize = 2_000;
const N_CLIENTS: usize = 4;
const REQS_PER_CLIENT: usize = 50;
const EPS: f64 = 0.1;

fn main() {
    // ---- L1/L2 artifacts -> runtime engine --------------------------
    let engine = select_engine(true, "artifacts", 1);
    println!("distance engine: {}", engine.name());
    if engine.name() != "pjrt" {
        println!("  (run `make artifacts` first for the PJRT/Pallas path)");
    }

    // ---- workload + deployments -------------------------------------
    let all = make_classification(
        &ClassificationSpec {
            n_samples: N_TRAIN + N_CLIENTS * REQS_PER_CLIENT,
            n_features: 30,
            ..Default::default()
        },
        1,
    );
    let mut rng = Rng::seed_from(2);
    let (train, test) = all.split(N_TRAIN, &mut rng);
    let cfg = MeasureConfig::default();
    let registry = Arc::new(Registry::new());
    for (name, kind) in [
        ("sknn", MeasureKind::SimplifiedKnn),
        ("kde", MeasureKind::Kde),
    ] {
        let t0 = std::time::Instant::now();
        registry.insert(Deployment::train(
            name,
            kind,
            &cfg,
            &train,
            Some(engine.clone()),
        ));
        println!("deployment {name:<5} trained on n={N_TRAIN} in {:?}", t0.elapsed());
    }

    // ---- L3 server ---------------------------------------------------
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 2,
            max_batch: 16,
            max_wait_us: 500,
            ..Default::default()
        },
        registry,
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv = server.clone();
    let server_thread = std::thread::spawn(move || serve(srv, listener));
    println!("coordinator serving on {addr}");

    // ---- concurrent clients ------------------------------------------
    let t0 = std::time::Instant::now();
    let results: Vec<(Vec<Vec<f64>>, Vec<usize>)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..N_CLIENTS {
            let test = &test;
            handles.push(s.spawn(move || {
                let mut conn = TcpStream::connect(addr).unwrap();
                let mut reader =
                    BufReader::new(conn.try_clone().unwrap());
                let mut p_rows = Vec::new();
                let mut truths = Vec::new();
                for r in 0..REQS_PER_CLIENT {
                    let i = c * REQS_PER_CLIENT + r;
                    let dep = if i % 2 == 0 { "sknn" } else { "kde" };
                    let req = Json::obj(vec![
                        ("op", Json::Str("predict".into())),
                        ("deployment", Json::Str(dep.into())),
                        ("x", Json::from_f64_slice(test.row(i))),
                        ("epsilon", Json::Num(EPS)),
                        ("id", Json::Num(i as f64)),
                    ]);
                    conn.write_all(req.encode().as_bytes()).unwrap();
                    conn.write_all(b"\n").unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let resp = Json::parse(line.trim()).unwrap();
                    p_rows.push(
                        resp.get("p_values").unwrap().as_f64_vec().unwrap(),
                    );
                    truths.push(test.y[i]);
                }
                (p_rows, truths)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed();
    let total_reqs = N_CLIENTS * REQS_PER_CLIENT;

    // ---- online updates through the wire -----------------------------
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut rpc = |req: Json| -> Json {
        conn.write_all(req.encode().as_bytes()).unwrap();
        conn.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };
    let learn = rpc(Json::obj(vec![
        ("op", Json::Str("learn".into())),
        ("deployment", Json::Str("sknn".into())),
        ("x", Json::from_f64_slice(test.row(0))),
        ("y", Json::Num(test.y[0] as f64)),
    ]));
    assert_eq!(learn.get("n_train").unwrap().as_f64(), Some((N_TRAIN + 1) as f64));
    let unlearn = rpc(Json::obj(vec![
        ("op", Json::Str("unlearn".into())),
        ("deployment", Json::Str("sknn".into())),
        ("index", Json::Num(N_TRAIN as f64)),
    ]));
    assert_eq!(unlearn.get("n_train").unwrap().as_f64(), Some(N_TRAIN as f64));
    println!("online learn/unlearn round-trip ✓");

    let stats = rpc(Json::parse(r#"{"op":"stats"}"#).unwrap());
    let _ = rpc(Json::parse(r#"{"op":"shutdown"}"#).unwrap());
    server_thread.join().unwrap().unwrap();

    // ---- report -------------------------------------------------------
    let mut p_matrix = Vec::new();
    let mut truth = Vec::new();
    for (rows, ts) in results {
        p_matrix.extend(rows);
        truth.extend(ts);
    }
    let cov = coverage(&p_matrix, &truth, EPS);
    println!("\n== end-to-end report ==");
    println!("requests        : {total_reqs} over {N_CLIENTS} connections");
    println!("wall time       : {wall:?}");
    println!(
        "throughput      : {:.0} predictions/s",
        total_reqs as f64 / wall.as_secs_f64()
    );
    for key in ["mean_batch_size", "mean_latency_us", "p50_latency_us", "p99_latency_us"] {
        println!(
            "{key:<16}: {:.1}",
            stats.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN)
        );
    }
    println!(
        "coverage        : {:.1}% at eps={EPS} (guarantee >= {:.0}%)",
        cov * 100.0,
        (1.0 - EPS) * 100.0
    );
    assert!(cov >= 1.0 - EPS - 0.08, "conformal guarantee violated");
    println!("end-to-end pipeline OK ✓");
}
