//! Online exchangeability testing (Vovk et al. 2003) — change-point
//! detection with exchangeability martingales, made practical by the
//! incremental k-NN measure (App. C.5: O(n^2) total instead of O(n^3)).
//!
//! Scenario: a data stream drifts at t = 400; the simple-mixture
//! martingale crosses the Ville alarm bar shortly after.
//!
//! ```sh
//! cargo run --release --example online_drift
//! ```

use exact_cp::data::Rng;
use exact_cp::measures::knn::KnnOptimized;
use exact_cp::online::ExchangeabilityTest;

fn main() {
    let dim = 4;
    let drift_at = 400;
    let n_total = 700;
    let alarm = 100f64.ln(); // Ville: P(ever exceeding 100) <= 1/100

    let mut rng = Rng::seed_from(99);
    let mut tester =
        ExchangeabilityTest::new(KnnOptimized::new(7, true), dim, 1);

    let mut alarm_step: Option<usize> = None;
    let t0 = std::time::Instant::now();
    for t in 0..n_total {
        // pre-drift: N(0, I); post-drift: mean shifts to 3.0
        let shift = if t >= drift_at { 3.0 } else { 0.0 };
        let x: Vec<f64> = (0..dim).map(|_| shift + rng.normal()).collect();
        tester.observe(&x);
        let lm = tester.log_martingale();
        if t % 100 == 99 {
            println!("t={:>4}  log10 M = {:>8.2}", t + 1, lm / 10f64.ln());
        }
        if lm > alarm && alarm_step.is_none() {
            alarm_step = Some(t);
        }
    }
    println!(
        "processed {n_total} observations in {:?} (incremental p-values)",
        t0.elapsed()
    );
    match alarm_step {
        Some(t) => {
            println!(
                "ALARM at t = {t} (drift injected at t = {drift_at}; \
                 detection delay = {})",
                t as i64 - drift_at as i64
            );
            assert!(t >= drift_at, "no false alarm before the drift");
            assert!(t < drift_at + 150, "detection should be prompt");
        }
        None => panic!("martingale never crossed the alarm bar"),
    }
}
