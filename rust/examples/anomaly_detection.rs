//! Conformal anomaly detection on streaming trajectory-like data — the
//! Laxhammar & Falkman (2010) use case the paper's Simplified k-NN
//! measure targets (§3, §9), with the optimized measure making each
//! query O(n) and online learning cheap.
//!
//! Scenario: a sensor emits 2-D positions from two normal modes; we
//! train the detector on normal traffic, then stream a mix of normal
//! points and injected anomalies, learning confirmed-normal points
//! online as we go.
//!
//! ```sh
//! cargo run --release --example anomaly_detection
//! ```

use exact_cp::cluster::AnomalyDetector;
use exact_cp::data::Rng;
use exact_cp::measures::knn::KnnOptimized;

/// Two-mode normal traffic around (0,0) and (6,6).
fn normal_point(rng: &mut Rng) -> [f64; 2] {
    let mode = rng.below(2) as f64 * 6.0;
    [mode + 0.8 * rng.normal(), mode + 0.8 * rng.normal()]
}

fn main() {
    let mut rng = Rng::seed_from(2026);
    // 1. Train on 600 normal observations.
    let train: Vec<f64> = (0..600).flat_map(|_| normal_point(&mut rng)).collect();
    let eps = 0.05; // guaranteed <= 5% false-alarm rate
    let t0 = std::time::Instant::now();
    let mut det = AnomalyDetector::train(KnnOptimized::new(10, true), &train, 2, eps);
    println!("trained detector on 600 normal points in {:?}", t0.elapsed());

    // 2. Stream 300 points; every 10th is an injected anomaly.
    let (mut tp, mut fp, mut fnn, mut tn) = (0, 0, 0, 0);
    let t0 = std::time::Instant::now();
    for i in 0..300 {
        let (pt, is_anomaly) = if i % 10 == 9 {
            // anomaly: far off the normal modes
            ([12.0 + rng.normal(), -6.0 + rng.normal()], true)
        } else {
            (normal_point(&mut rng), false)
        };
        let flagged = det.is_anomaly(&pt);
        match (flagged, is_anomaly) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, true) => fnn += 1,
            (false, false) => {
                tn += 1;
                // confirmed normal: learn it online (O(n) with the
                // optimized measure — §9's online setting)
                det.learn(&pt);
            }
        }
    }
    let elapsed = t0.elapsed();
    println!(
        "streamed 300 points in {elapsed:?} ({:?}/point, detector grew to \
         {} references online)",
        elapsed / 300,
        600 + tn
    );
    println!("  true alarms   : {tp}/30");
    println!("  missed        : {fnn}/30");
    println!(
        "  false alarms  : {fp}/270 = {:.1}% (guarantee <= {:.0}%)",
        100.0 * fp as f64 / 270.0,
        eps * 100.0
    );
    println!("  true negatives: {tn}");
    assert!(
        (fp as f64 / 270.0) < eps + 0.05,
        "false alarm rate should respect the conformal guarantee"
    );
    assert!(tp >= 25, "detector should catch most injected anomalies");
}
