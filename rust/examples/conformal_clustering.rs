//! Conformal clustering (Cherubin et al. 2015; paper §9): lay a grid
//! over the data, keep cells whose conformal p-value exceeds eps, and
//! read clusters off the connected components. With the optimized
//! Simplified k-NN measure the grid scan costs O(n q^2) instead of
//! O(n^2 q^2) — the §9 accounting this example also measures.
//!
//! ```sh
//! cargo run --release --example conformal_clustering
//! ```

use exact_cp::cluster::conformal_clustering;
use exact_cp::data::Rng;
use exact_cp::measures::knn::{KnnOptimized, KnnStandard};

/// three Gaussian blobs in 5-D (clustering runs on the PCA-2 plane)
fn blobs(n_per: usize, seed: u64) -> Vec<f64> {
    let centers = [
        [0.0, 0.0, 0.0, 0.0, 0.0],
        [8.0, 8.0, 0.0, 0.0, 0.0],
        [0.0, 9.0, 1.0, 0.0, 0.0],
    ];
    let mut rng = Rng::seed_from(seed);
    let mut out = Vec::with_capacity(n_per * centers.len() * 5);
    for c in &centers {
        for _ in 0..n_per {
            for &cc in c {
                out.push(cc + 0.7 * rng.normal());
            }
        }
    }
    out
}

fn main() {
    let n_per = 120;
    let x = blobs(n_per, 11);
    let q = 30; // grid side
    let eps = 0.07;

    let t0 = std::time::Instant::now();
    let clustering =
        conformal_clustering(KnnOptimized::new(7, true), &x, 5, q, eps);
    let t_opt = t0.elapsed();
    println!(
        "optimized:  {} clusters over a {q}x{q} grid in {t_opt:?}",
        clustering.n_clusters
    );

    let t0 = std::time::Instant::now();
    let std_clustering =
        conformal_clustering(KnnStandard::new(7, true), &x, 5, q, eps);
    let t_std = t0.elapsed();
    println!(
        "standard:   {} clusters over the same grid in {t_std:?} \
         ({:.0}x slower, same result)",
        std_clustering.n_clusters,
        t_std.as_secs_f64() / t_opt.as_secs_f64().max(1e-9)
    );
    assert_eq!(clustering.n_clusters, 3, "three blobs, three clusters");
    assert_eq!(
        clustering.cell_cluster, std_clustering.cell_cluster,
        "exactness: identical cell p-value decisions"
    );

    // cluster membership purity: points from one blob share an id
    for b in 0..3 {
        let ids = &clustering.point_cluster[b * n_per..(b + 1) * n_per];
        let rep = ids.iter().find(|&&i| i != usize::MAX).copied().unwrap();
        let agree = ids.iter().filter(|&&i| i == rep).count();
        println!(
            "blob {b}: {}/{} points in cluster {rep} ({} noise)",
            agree,
            n_per,
            ids.iter().filter(|&&i| i == usize::MAX).count()
        );
        assert!(agree * 10 >= n_per * 8, "blob {b} purity too low");
    }
    println!("conformal clustering OK ✓");
}
