//! Full-CP regression (§8): exact prediction regions from the k-NN CP
//! regressor (our optimization of Papadopoulos et al. 2011) and the
//! ridge RRCM, vs the ICP baseline — on a noisy linear workload.
//!
//! ```sh
//! cargo run --release --example regression_intervals
//! ```

use exact_cp::data::{make_regression, RegressionSpec, Rng};
use exact_cp::regression::{
    IcpKnnRegressor, KnnRegressorOptimized, KnnRegressorStandard, RidgeCp,
};

fn main() {
    let all = make_regression(
        &RegressionSpec {
            n_samples: 1_050,
            n_features: 30,
            n_informative: 10,
            noise: 15.0,
        },
        3,
    );
    let mut rng = Rng::seed_from(4);
    let (train, test) = all.split(1_000, &mut rng);
    let eps = 0.1;

    // our optimized full k-NN CP regressor
    let t0 = std::time::Instant::now();
    let mut knn = KnnRegressorOptimized::new(15);
    knn.fit(&train);
    println!("optimized k-NN CP regressor: trained in {:?}", t0.elapsed());

    // ridge RRCM (linear model — should be much tighter here)
    let mut ridge = RidgeCp::new(1.0);
    ridge.fit(&train);

    // ICP baseline
    let mut icp = IcpKnnRegressor::new(15);
    icp.fit(&train, 500);

    let (mut cov_knn, mut cov_ridge, mut cov_icp) = (0, 0, 0);
    let (mut w_knn, mut w_ridge, mut w_icp) = (0.0, 0.0, 0.0);
    let t0 = std::time::Instant::now();
    for i in 0..test.n() {
        let x = test.row(i);
        let y = test.y[i];
        let r_knn = knn.predict_region(x, eps);
        let r_ridge = ridge.predict_region(x, eps);
        let (lo, hi) = icp.predict_interval(x, eps);
        cov_knn += r_knn.contains(y) as usize;
        cov_ridge += r_ridge.contains(y) as usize;
        cov_icp += (lo <= y && y <= hi) as usize;
        w_knn += r_knn.hull().map(|h| h.width()).unwrap_or(f64::NAN);
        w_ridge += r_ridge.hull().map(|h| h.width()).unwrap_or(f64::NAN);
        w_icp += hi - lo;
        if i < 3 {
            println!(
                "  x[{i}] true={y:>8.1}  knn={:?}  ridge={:?}  icp=[{lo:.1}, {hi:.1}]",
                r_knn.hull().unwrap(),
                r_ridge.hull().unwrap(),
            );
        }
    }
    let n = test.n() as f64;
    println!(
        "{} predictions in {:?} ({:?}/point)",
        test.n(),
        t0.elapsed(),
        t0.elapsed() / test.n() as u32
    );
    println!(
        "method       coverage (target >= {:.0}%)   mean width",
        (1.0 - eps) * 100.0
    );
    println!(
        "  knn-cp     {:>5.1}%                      {:>8.1}",
        100.0 * cov_knn as f64 / n,
        w_knn / n
    );
    println!(
        "  ridge-cp   {:>5.1}%                      {:>8.1}",
        100.0 * cov_ridge as f64 / n,
        w_ridge / n
    );
    println!(
        "  knn-icp    {:>5.1}%                      {:>8.1}",
        100.0 * cov_icp as f64 / n,
        w_icp / n
    );

    // exactness vs the Papadopoulos-2011 reference on a small subset
    let (small, _) = train.split(150, &mut rng);
    let mut std_m = KnnRegressorStandard::new(15);
    let mut opt_m = KnnRegressorOptimized::new(15);
    std_m.fit(&small);
    opt_m.fit(&small);
    let x = test.row(0);
    assert_eq!(
        std_m.predict_region(x, eps),
        opt_m.predict_region(x, eps),
        "optimized regressor must match Papadopoulos et al. exactly"
    );
    println!("exactness vs Papadopoulos-2011: regions identical ✓");

    // batched serving path: the test-independent work is hoisted once
    // per batch, and the results are bit-identical to the per-object
    // loop (the exactness contract of `exact_cp::regression`)
    let m_batch = 16.min(test.n());
    let xs: Vec<&[f64]> = (0..m_batch).map(|i| test.row(i)).collect();
    let t0 = std::time::Instant::now();
    let batch = knn.predict_region_batch(&xs, eps);
    let t_batch = t0.elapsed();
    for (region, &xi) in batch.iter().zip(&xs) {
        assert_eq!(*region, knn.predict_region(xi, eps), "batch == single");
    }
    let ps = ridge.p_values_batch(&xs, &test.y[..m_batch]);
    for (i, &xi) in xs.iter().enumerate() {
        assert_eq!(ps[i], ridge.p_value(xi, test.y[i]), "batch p-value");
    }
    println!(
        "batched API smoke test: {m_batch} regions in {t_batch:?}, \
         bit-identical to the per-object loop ✓"
    );
}
