//! Minimal in-tree shim for the `anyhow` crate.
//!
//! The build environment is offline (no crates.io), so this crate
//! provides the subset of anyhow's API that exact-cp actually uses:
//!
//! * [`Error`] — an opaque, `Display`able error value;
//! * [`Result`] — `Result<T, Error>` with a defaultable error type;
//! * [`anyhow!`] / [`bail!`] — format-style error construction;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (both foreign errors and `anyhow::Error`) and on `Option`.
//!
//! Semantics match upstream where it matters here: any
//! `std::error::Error + Send + Sync + 'static` converts via `?`,
//! context wraps as `"{context}: {source}"`, and `Error` deliberately
//! does NOT implement `std::error::Error` (exactly like upstream, so
//! the blanket `From` impl cannot conflict with `From<Error>`).

use std::fmt;

/// Opaque error: a rendered message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prepend a context layer.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Render the source chain eagerly; the shim stores no boxes.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>`: defaultable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("boom"));
    }

    #[test]
    fn context_layers_compose() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: boom");
        let e2: Result<()> = Err(e);
        let e2 = e2.with_context(|| format!("run {}", 7)).unwrap_err();
        assert_eq!(e2.to_string(), "run 7: reading config: boom");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(3u8).context("unused").unwrap(), 3);
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("bad flag {flag:?}");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "bad flag true");
        let e = anyhow!("x = {}", 42);
        assert_eq!(format!("{e}"), "x = 42");
        assert_eq!(format!("{e:?}"), "x = 42");
    }
}
