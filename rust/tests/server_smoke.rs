//! Server smoke test: boot the full TCP front end on an ephemeral
//! port with observability on, drive one of each observability op over
//! the wire, and assert every response is well-formed JSON with the
//! documented shape (PROTOCOL.md).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use exact_cp::config::{MeasureConfig, MeasureKind, ObsConfig, ServeConfig};
use exact_cp::coordinator::server::{serve, Server};
use exact_cp::coordinator::state::{Deployment, Registry};
use exact_cp::data::{make_classification, ClassificationSpec};
use exact_cp::util::json::Json;

/// Tests that flip the process-global trace switch serialize on this
/// lock (the ring and the enabled flag are shared process state).
static TRACE_GATE: Mutex<()> = Mutex::new(());

fn send(stream: &mut TcpStream, req: &str) -> Json {
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap_or_else(|e| {
        panic!("response not well-formed JSON ({e}): {line:?}")
    })
}

#[test]
fn smoke_predict_stats_trace_over_tcp() {
    let _gate = TRACE_GATE.lock().unwrap();
    let ds = make_classification(
        &ClassificationSpec {
            n_samples: 60,
            ..Default::default()
        },
        1,
    );
    let reg = Arc::new(Registry::new());
    reg.insert(Deployment::train(
        "sknn",
        MeasureKind::SimplifiedKnn,
        &MeasureConfig {
            k: 5,
            ..Default::default()
        },
        &ds,
        None,
    ));
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 2,
            max_wait_us: 200,
            obs: ObsConfig {
                trace: true,
                ring_capacity: 4096,
                epsilons: vec![0.1],
            },
            ..Default::default()
        },
        reg,
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv2 = server.clone();
    let handle = std::thread::spawn(move || serve(srv2, listener));

    let mut conn = TcpStream::connect(addr).unwrap();

    // labeled predict: feeds both the op block and the validity monitor
    let x: Vec<String> = (0..30).map(|_| "0.1".to_string()).collect();
    let resp = send(
        &mut conn,
        &format!(
            r#"{{"op":"predict","deployment":"sknn","x":[{}],"epsilon":0.1,"y":1}}"#,
            x.join(",")
        ),
    );
    let ps = resp.get("p_values").unwrap().as_f64_vec().unwrap();
    assert_eq!(ps.len(), 2);
    assert!(ps.iter().all(|&p| (0.0..=1.0).contains(&p)));

    // stats: per-deployment block reflects the one predict
    let stats = send(&mut conn, r#"{"op":"stats"}"#);
    for key in ["deployments", "epsilons", "testers", "trace", "requests"] {
        assert!(stats.get(key).is_some(), "stats missing {key}");
    }
    let dep = stats.get("deployments").unwrap().get("sknn").unwrap();
    let predict = dep.get("ops").unwrap().get("predict").unwrap();
    assert_eq!(predict.get("requests").and_then(Json::as_f64), Some(1.0));
    let track = &dep
        .get("validity")
        .unwrap()
        .get("per_epsilon")
        .unwrap()
        .as_arr()
        .unwrap()[0];
    assert_eq!(track.get("epsilon").and_then(Json::as_f64), Some(0.1));
    assert_eq!(track.get("labeled").and_then(Json::as_f64), Some(1.0));

    // trace: the ring saw the predict's pipeline stages
    let trace = send(&mut conn, r#"{"op":"trace"}"#);
    assert_eq!(trace.get("enabled").and_then(Json::as_bool), Some(true));
    let evs = trace.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!evs.is_empty(), "trace ring empty after traffic");
    for e in evs {
        assert!(e.get("name").and_then(Json::as_str).is_some());
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
    }

    let bye = send(&mut conn, r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    handle.join().unwrap().unwrap();
    exact_cp::obs::trace::set_enabled(false);
}

/// Regression-deployment smoke: boot the TCP front end with tracing on,
/// drive predict_region + unlearn (ok and out-of-range) over the wire,
/// and assert the documented shapes (PROTOCOL.md "unlearn") — including
/// the per-deployment unlearn op block now firing for regression.
#[test]
fn smoke_regression_unlearn_over_tcp() {
    let _gate = TRACE_GATE.lock().unwrap();
    use exact_cp::config::RegressorKind;
    use exact_cp::data::{make_regression, RegressionSpec};

    let rds = make_regression(
        &RegressionSpec {
            n_samples: 50,
            n_features: 4,
            n_informative: 3,
            noise: 3.0,
        },
        9,
    );
    let reg = Arc::new(Registry::new());
    reg.insert(Deployment::train_regression(
        "rrcm",
        RegressorKind::Ridge,
        &MeasureConfig::default(),
        &rds,
        None,
    ));
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 2,
            max_wait_us: 200,
            obs: ObsConfig {
                trace: true,
                ring_capacity: 4096,
                epsilons: vec![0.1],
            },
            ..Default::default()
        },
        reg,
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv2 = server.clone();
    let handle = std::thread::spawn(move || serve(srv2, listener));

    let mut conn = TcpStream::connect(addr).unwrap();

    // decremental update: ok:true, shrunken n_train, bumped version
    let resp = send(
        &mut conn,
        r#"{"op":"unlearn","deployment":"rrcm","index":49}"#,
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(resp.get("n_train").and_then(Json::as_f64), Some(49.0));
    assert_eq!(resp.get("version").and_then(Json::as_f64), Some(1.0));

    // out-of-range: ok:false with a structured error string
    let resp = send(
        &mut conn,
        r#"{"op":"unlearn","deployment":"rrcm","index":49}"#,
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert!(resp
        .get("error")
        .and_then(Json::as_str)
        .unwrap()
        .contains("out of range"));

    // serving still works on the reduced set
    let resp = send(
        &mut conn,
        r#"{"op":"predict_region","deployment":"rrcm","x":[0.2,0.1,0.0,0.3],"epsilon":0.1}"#,
    );
    assert!(resp.get("intervals").is_some(), "{}", resp.encode());

    // stats: the regression deployment's unlearn op block fired
    let stats = send(&mut conn, r#"{"op":"stats"}"#);
    let dep = stats.get("deployments").unwrap().get("rrcm").unwrap();
    let un = dep.get("ops").unwrap().get("unlearn").unwrap();
    assert_eq!(un.get("requests").and_then(Json::as_f64), Some(2.0));
    assert_eq!(un.get("errors").and_then(Json::as_f64), Some(1.0));
    assert!(un.get("latency_us").is_some());

    let bye = send(&mut conn, r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    handle.join().unwrap().unwrap();
    exact_cp::obs::trace::set_enabled(false);
}
