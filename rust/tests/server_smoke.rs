//! Server smoke test: boot the full TCP front end on an ephemeral
//! port with observability on, drive one of each observability op over
//! the wire, and assert every response is well-formed JSON with the
//! documented shape (PROTOCOL.md).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use exact_cp::config::{MeasureConfig, MeasureKind, ObsConfig, ServeConfig};
use exact_cp::coordinator::server::{serve, Server};
use exact_cp::coordinator::state::{Deployment, Registry};
use exact_cp::data::{make_classification, ClassificationSpec};
use exact_cp::util::json::Json;

fn send(stream: &mut TcpStream, req: &str) -> Json {
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap_or_else(|e| {
        panic!("response not well-formed JSON ({e}): {line:?}")
    })
}

#[test]
fn smoke_predict_stats_trace_over_tcp() {
    let ds = make_classification(
        &ClassificationSpec {
            n_samples: 60,
            ..Default::default()
        },
        1,
    );
    let reg = Arc::new(Registry::new());
    reg.insert(Deployment::train(
        "sknn",
        MeasureKind::SimplifiedKnn,
        &MeasureConfig {
            k: 5,
            ..Default::default()
        },
        &ds,
        None,
    ));
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 2,
            max_wait_us: 200,
            obs: ObsConfig {
                trace: true,
                ring_capacity: 4096,
                epsilons: vec![0.1],
            },
            ..Default::default()
        },
        reg,
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv2 = server.clone();
    let handle = std::thread::spawn(move || serve(srv2, listener));

    let mut conn = TcpStream::connect(addr).unwrap();

    // labeled predict: feeds both the op block and the validity monitor
    let x: Vec<String> = (0..30).map(|_| "0.1".to_string()).collect();
    let resp = send(
        &mut conn,
        &format!(
            r#"{{"op":"predict","deployment":"sknn","x":[{}],"epsilon":0.1,"y":1}}"#,
            x.join(",")
        ),
    );
    let ps = resp.get("p_values").unwrap().as_f64_vec().unwrap();
    assert_eq!(ps.len(), 2);
    assert!(ps.iter().all(|&p| (0.0..=1.0).contains(&p)));

    // stats: per-deployment block reflects the one predict
    let stats = send(&mut conn, r#"{"op":"stats"}"#);
    for key in ["deployments", "epsilons", "testers", "trace", "requests"] {
        assert!(stats.get(key).is_some(), "stats missing {key}");
    }
    let dep = stats.get("deployments").unwrap().get("sknn").unwrap();
    let predict = dep.get("ops").unwrap().get("predict").unwrap();
    assert_eq!(predict.get("requests").and_then(Json::as_f64), Some(1.0));
    let track = &dep
        .get("validity")
        .unwrap()
        .get("per_epsilon")
        .unwrap()
        .as_arr()
        .unwrap()[0];
    assert_eq!(track.get("epsilon").and_then(Json::as_f64), Some(0.1));
    assert_eq!(track.get("labeled").and_then(Json::as_f64), Some(1.0));

    // trace: the ring saw the predict's pipeline stages
    let trace = send(&mut conn, r#"{"op":"trace"}"#);
    assert_eq!(trace.get("enabled").and_then(Json::as_bool), Some(true));
    let evs = trace.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!evs.is_empty(), "trace ring empty after traffic");
    for e in evs {
        assert!(e.get("name").and_then(Json::as_str).is_some());
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
    }

    let bye = send(&mut conn, r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    handle.join().unwrap().unwrap();
    exact_cp::obs::trace::set_enabled(false);
}
