//! Integration tests for the L3 serving coordinator: end-to-end TCP
//! round trips, batching behaviour under concurrent load, online
//! updates through the wire protocol, and backpressure.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use exact_cp::config::{MeasureConfig, MeasureKind, RegressorKind, ServeConfig};
use exact_cp::coordinator::server::{serve, Server};
use exact_cp::coordinator::state::{Deployment, Registry};
use exact_cp::data::{
    make_classification, make_regression, ClassificationSpec, RegressionSpec,
};
use exact_cp::util::json::Json;

fn registry(n: usize) -> Arc<Registry> {
    let ds = make_classification(
        &ClassificationSpec {
            n_samples: n,
            ..Default::default()
        },
        1,
    );
    let reg = Arc::new(Registry::new());
    let cfg = MeasureConfig {
        k: 5,
        ..Default::default()
    };
    reg.insert(Deployment::train(
        "sknn",
        MeasureKind::SimplifiedKnn,
        &cfg,
        &ds,
        None,
    ));
    reg.insert(Deployment::train("kde", MeasureKind::Kde, &cfg, &ds, None));
    reg
}

/// Classification registry plus two regression deployments ("reg" =
/// optimized k-NN regressor, "rrcm" = ridge) trained on the same
/// synthetic 4-feature regression set.
fn mixed_registry(n: usize) -> Arc<Registry> {
    let reg = registry(n);
    let rds = make_regression(
        &RegressionSpec {
            n_samples: n,
            n_features: 4,
            n_informative: 3,
            noise: 3.0,
        },
        5,
    );
    let cfg = MeasureConfig {
        k: 3,
        ..Default::default()
    };
    reg.insert(Deployment::train_regression(
        "reg",
        RegressorKind::Knn,
        &cfg,
        &rds,
        None,
    ));
    reg.insert(Deployment::train_regression(
        "rrcm",
        RegressorKind::Ridge,
        &cfg,
        &rds,
        None,
    ));
    reg
}

fn send(stream: &mut TcpStream, req: &str) -> Json {
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap()
}

fn x30() -> String {
    let v: Vec<String> = (0..30).map(|_| "0.1".to_string()).collect();
    format!("[{}]", v.join(","))
}

#[test]
fn tcp_end_to_end() {
    let reg = registry(120);
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 2,
            max_wait_us: 200,
            ..Default::default()
        },
        reg,
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv2 = server.clone();
    let handle = std::thread::spawn(move || serve(srv2, listener));

    let mut conn = TcpStream::connect(addr).unwrap();
    // ping
    let pong = send(&mut conn, r#"{"op":"ping"}"#);
    assert_eq!(pong.get("ok").and_then(Json::as_bool), Some(true));
    // list
    let list = send(&mut conn, r#"{"op":"list"}"#);
    assert_eq!(list.get("deployments").unwrap().as_arr().unwrap().len(), 2);
    // predict on both deployments
    for dep in ["sknn", "kde"] {
        let resp = send(
            &mut conn,
            &format!(
                r#"{{"op":"predict","deployment":"{dep}","x":{},"epsilon":0.1,"id":3}}"#,
                x30()
            ),
        );
        let ps = resp.get("p_values").unwrap().as_f64_vec().unwrap();
        assert_eq!(ps.len(), 2, "{dep}");
        assert!(ps.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
    // online learn then predict again
    let learn = send(
        &mut conn,
        &format!(
            r#"{{"op":"learn","deployment":"sknn","x":{},"y":1}}"#,
            x30()
        ),
    );
    assert_eq!(learn.get("n_train").unwrap().as_f64(), Some(121.0));
    // stats reflect traffic
    let stats = send(&mut conn, r#"{"op":"stats"}"#);
    assert!(stats.get("predictions").unwrap().as_f64().unwrap() >= 2.0);
    // shutdown
    let bye = send(&mut conn, r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    handle.join().unwrap().unwrap();
}

#[test]
fn concurrent_clients_get_consistent_answers() {
    let reg = registry(80);
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 3,
            max_batch: 8,
            max_wait_us: 500,
            ..Default::default()
        },
        reg,
    ));
    // 4 in-process clients x 10 predictions each, all identical requests
    let req = Json::parse(&format!(
        r#"{{"op":"predict","deployment":"sknn","x":{},"epsilon":0.1}}"#,
        x30()
    ))
    .unwrap();
    let mut answers: Vec<Vec<f64>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..4 {
            let srv = server.clone();
            let rq = req.clone();
            handles.push(s.spawn(move || {
                (0..10)
                    .map(|_| {
                        srv.handle(&rq)
                            .get("p_values")
                            .unwrap()
                            .as_f64_vec()
                            .unwrap()
                    })
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            answers.extend(h.join().unwrap());
        }
    });
    assert_eq!(answers.len(), 40);
    for a in &answers[1..] {
        assert_eq!(a, &answers[0], "identical queries must agree");
    }
    // batching actually happened (fewer batches than items)
    let stats = server.metrics.snapshot();
    let batches = stats.get("batches").unwrap().as_f64().unwrap();
    assert!(batches >= 1.0);
}

#[test]
fn mixed_deployment_batches_route_correctly() {
    // Concurrent traffic for two deployments lands in shared dynamic
    // batches; the worker must group per deployment and every client
    // must get the answer for ITS deployment, identical to the
    // unbatched single-object path.
    let reg = registry(60);
    let expected_sknn = reg
        .with("sknn", |d| d.p_values(&[0.1; 30]))
        .unwrap();
    let expected_kde = reg
        .with("kde", |d| d.p_values(&[0.1; 30]))
        .unwrap();
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 2,
            max_batch: 16,
            max_wait_us: 2_000,
            ..Default::default()
        },
        reg,
    ));
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for dep in ["sknn", "kde", "sknn", "kde", "sknn", "kde"] {
            let srv = server.clone();
            handles.push(s.spawn(move || {
                let req = Json::parse(&format!(
                    r#"{{"op":"predict","deployment":"{dep}","x":{},"epsilon":0.1}}"#,
                    x30()
                ))
                .unwrap();
                (dep, srv.handle(&req))
            }));
        }
        for h in handles {
            let (dep, resp) = h.join().unwrap();
            let ps = resp
                .get("p_values")
                .unwrap_or_else(|| panic!("{dep}: {}", resp.encode()))
                .as_f64_vec()
                .unwrap();
            let want = if dep == "sknn" {
                &expected_sknn
            } else {
                &expected_kde
            };
            assert_eq!(&ps, want, "{dep} answer must match unbatched path");
        }
    });
}

#[test]
fn unlearn_then_predict_still_works() {
    let reg = registry(50);
    let server = Arc::new(Server::start(ServeConfig::default(), reg));
    let un = Json::parse(r#"{"op":"unlearn","deployment":"sknn","index":0}"#).unwrap();
    let resp = server.handle(&un);
    assert_eq!(resp.get("n_train").unwrap().as_f64(), Some(49.0));
    let pr = Json::parse(&format!(
        r#"{{"op":"predict","deployment":"sknn","x":{}}}"#,
        x30()
    ))
    .unwrap();
    let resp = server.handle(&pr);
    assert!(resp.get("p_values").is_some());
}

#[test]
fn tcp_predict_region_round_trip() {
    let reg = mixed_registry(40);
    let x = [0.3, -0.1, 0.2, 0.05];
    let expected = reg
        .with("reg", |d| d.predict_region(&x, 0.1, Some(1.0)))
        .unwrap()
        .unwrap();
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 2,
            max_wait_us: 200,
            ..Default::default()
        },
        reg,
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv2 = server.clone();
    let handle = std::thread::spawn(move || serve(srv2, listener));

    let mut conn = TcpStream::connect(addr).unwrap();
    let resp = send(
        &mut conn,
        r#"{"op":"predict_region","deployment":"reg","x":[0.3,-0.1,0.2,0.05],"epsilon":0.1,"y":1.0,"id":7}"#,
    );
    assert_eq!(resp.get("id").and_then(Json::as_f64), Some(7.0));
    let intervals = resp.get("intervals").unwrap().as_arr().unwrap();
    assert_eq!(intervals.len(), expected.region.intervals.len());
    for (iv, want) in intervals.iter().zip(&expected.region.intervals) {
        // finite endpoints survive the wire bit-exactly (shortest
        // round-trip float formatting)
        assert_eq!(iv.as_f64_vec().unwrap(), vec![want.lo, want.hi]);
    }
    assert_eq!(resp.get("p_value").and_then(Json::as_f64), expected.p_at_y);
    // the ridge deployment answers too; no candidate y -> no p_value
    let resp = send(
        &mut conn,
        r#"{"op":"predict_region","deployment":"rrcm","x":[0.3,-0.1,0.2,0.05],"epsilon":0.3}"#,
    );
    assert!(resp.get("intervals").is_some(), "{}", resp.encode());
    assert!(resp.get("p_value").is_none());
    let bye = send(&mut conn, r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    handle.join().unwrap().unwrap();
}

#[test]
fn mixed_classification_and_regression_batches() {
    // Concurrent predict + predict_region traffic shares the dynamic
    // batcher; the worker must split jobs by deployment AND op kind,
    // and every answer must match its unbatched single-object path.
    let reg = mixed_registry(40);
    let expected_ps = reg.with("sknn", |d| d.p_values(&[0.1; 30])).unwrap();
    let expected_region = reg
        .with("reg", |d| d.predict_region(&[0.0; 4], 0.1, None))
        .unwrap()
        .unwrap();
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 2,
            max_batch: 16,
            max_wait_us: 2_000,
            ..Default::default()
        },
        reg,
    ));
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for i in 0..8 {
            let srv = server.clone();
            handles.push(s.spawn(move || {
                let req = if i % 2 == 0 {
                    Json::parse(&format!(
                        r#"{{"op":"predict","deployment":"sknn","x":{},"epsilon":0.1}}"#,
                        x30()
                    ))
                    .unwrap()
                } else {
                    Json::parse(
                        r#"{"op":"predict_region","deployment":"reg","x":[0,0,0,0],"epsilon":0.1}"#,
                    )
                    .unwrap()
                };
                (i, srv.handle(&req))
            }));
        }
        for h in handles {
            let (i, resp) = h.join().unwrap();
            if i % 2 == 0 {
                let ps = resp
                    .get("p_values")
                    .unwrap_or_else(|| panic!("{}", resp.encode()))
                    .as_f64_vec()
                    .unwrap();
                assert_eq!(ps, expected_ps, "classification answer drifted");
            } else {
                let ivs = resp
                    .get("intervals")
                    .unwrap_or_else(|| panic!("{}", resp.encode()))
                    .as_arr()
                    .unwrap();
                assert_eq!(ivs.len(), expected_region.region.intervals.len());
                for (iv, want) in
                    ivs.iter().zip(&expected_region.region.intervals)
                {
                    assert_eq!(
                        iv.as_f64_vec().unwrap(),
                        vec![want.lo, want.hi],
                        "region answer drifted"
                    );
                }
            }
        }
    });
}

#[test]
fn tcp_observe_round_trip() {
    let reg = registry(30);
    let server = Arc::new(Server::start(ServeConfig::default(), reg));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv2 = server.clone();
    let handle = std::thread::spawn(move || serve(srv2, listener));

    let mut conn = TcpStream::connect(addr).unwrap();
    // batched observe: the first row bootstraps the tester (null
    // p-value), the rest are scored against the batch-start state
    let resp = send(
        &mut conn,
        r#"{"op":"observe","tester":"drift","xs":[[0.0,0.0],[0.1,0.0],[0.0,0.2],[0.3,0.1]],"k":3,"seed":1}"#,
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    let ps = resp.get("p_values").unwrap().as_arr().unwrap();
    assert_eq!(ps.len(), 4);
    assert!(matches!(ps[0], Json::Null), "bootstrap p must be null");
    assert!(ps[1..].iter().all(|p| p.as_f64().is_some()));
    assert_eq!(resp.get("n").and_then(Json::as_f64), Some(4.0));
    assert!(resp.get("log_martingale").and_then(Json::as_f64).is_some());
    assert!(resp.get("alarm").and_then(Json::as_bool).is_some());
    // the tester persists: a follow-up single observation continues it
    let resp = send(
        &mut conn,
        r#"{"op":"observe","tester":"drift","x":[0.2,0.2]}"#,
    );
    assert_eq!(resp.get("n").and_then(Json::as_f64), Some(5.0));
    // dimension mismatch is a clean error, not a crash
    let resp = send(
        &mut conn,
        r#"{"op":"observe","tester":"drift","x":[1.0]}"#,
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    let bye = send(&mut conn, r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    handle.join().unwrap().unwrap();
}

#[test]
fn malformed_requests_do_not_crash() {
    let reg = registry(30);
    let server = Arc::new(Server::start(ServeConfig::default(), reg));
    for bad in [
        r#"{"op":"predict"}"#,
        r#"{"op":"learn","deployment":"sknn"}"#,
        r#"{"op":"unlearn","deployment":"sknn","index":9999}"#,
        r#"{"nonsense":true}"#,
    ] {
        let resp = server.handle(&Json::parse(bad).unwrap());
        assert_eq!(
            resp.get("ok").and_then(Json::as_bool),
            Some(false),
            "{bad}"
        );
    }
}

/// `[serve.deployment.X]` blocks round-trip end to end: config text ->
/// parsed specs -> trained deployments -> wire answers. Two ridge
/// deployments with different per-deployment rho must serve different
/// intervals side by side, and a classification spec rides along.
#[test]
fn per_deployment_hyperparameters_round_trip() {
    use exact_cp::config::Config;
    use exact_cp::coordinator::factory::deployment_from_spec;
    use exact_cp::util::toml_lite;

    let doc = toml_lite::parse(
        r#"
        [measure]
        k = 5
        [serve.deployment.stiff]
        kind = "ridge"
        rho = 100.0
        [serve.deployment.loose]
        kind = "ridge"
        rho = 0.01
        [serve.deployment.cls]
        kind = "simplified-knn"
        k = 3
        "#,
    )
    .unwrap();
    let cfg = Config::from_doc(&doc);
    assert_eq!(cfg.serve.deployments.len(), 3);

    let cls = make_classification(
        &ClassificationSpec {
            n_samples: 40,
            ..Default::default()
        },
        1,
    );
    let rds = make_regression(
        &RegressionSpec {
            n_samples: 40,
            n_features: 4,
            n_informative: 3,
            noise: 3.0,
        },
        5,
    );
    let reg = Arc::new(Registry::new());
    for spec in &cfg.serve.deployments {
        reg.insert(deployment_from_spec(spec, &cls, &rds, None).unwrap());
    }
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 1,
            max_wait_us: 200,
            ..Default::default()
        },
        reg,
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv2 = server.clone();
    let handle = std::thread::spawn(move || serve(srv2, listener));
    let mut conn = TcpStream::connect(addr).unwrap();

    let list = send(&mut conn, r#"{"op":"list"}"#);
    assert_eq!(list.get("deployments").unwrap().as_arr().unwrap().len(), 3);

    let mut widths = Vec::new();
    for dep in ["stiff", "loose"] {
        let resp = send(
            &mut conn,
            &format!(
                r#"{{"op":"predict_region","deployment":"{dep}","x":[0.2,0.1,0.0,0.3],"epsilon":0.1}}"#,
            ),
        );
        let ivs = resp
            .get("intervals")
            .unwrap_or_else(|| panic!("{}", resp.encode()))
            .as_arr()
            .unwrap();
        let w: f64 = ivs
            .iter()
            .map(|iv| {
                let b = iv.as_f64_vec().unwrap();
                b[1] - b[0]
            })
            .sum();
        assert!(w.is_finite() && w > 0.0, "{dep}: width {w}");
        widths.push(w);
    }
    assert!(
        (widths[0] - widths[1]).abs() > 1e-9,
        "per-deployment rho had no effect: widths {widths:?}"
    );

    let resp = send(
        &mut conn,
        &format!(
            r#"{{"op":"predict","deployment":"cls","x":{},"epsilon":0.1}}"#,
            x30()
        ),
    );
    assert_eq!(resp.get("p_values").unwrap().as_f64_vec().unwrap().len(), 2);

    send(&mut conn, r#"{"op":"shutdown"}"#);
    handle.join().unwrap().unwrap();
}

/// Acceptance (ISSUE 10): `op:"unlearn"` against ridge and k-NN
/// regression deployments succeeds over TCP, and subsequent
/// `predict_region` answers are bit-identical to a server freshly
/// trained on the reduced set (the wire uses shortest-round-trip float
/// formatting, so decoded-f64 equality is bit equality for finite
/// endpoints).
#[test]
fn tcp_regression_unlearn_matches_fresh_server() {
    let n = 40;
    let reg = mixed_registry(n);
    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 2,
            max_wait_us: 200,
            ..Default::default()
        },
        reg,
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv2 = server.clone();
    let handle = std::thread::spawn(move || serve(srv2, listener));
    let mut conn = TcpStream::connect(addr).unwrap();

    // the same regression set mixed_registry trains on, with rows 17
    // then 0 removed (matching the unlearn sequence below)
    let mut reduced = make_regression(
        &RegressionSpec {
            n_samples: n,
            n_features: 4,
            n_informative: 3,
            noise: 3.0,
        },
        5,
    );
    reduced.remove(17);
    reduced.remove(0);
    let cfg = MeasureConfig {
        k: 3,
        ..Default::default()
    };
    for (dep, kind) in
        [("reg", RegressorKind::Knn), ("rrcm", RegressorKind::Ridge)]
    {
        for (step, idx) in [17usize, 0].into_iter().enumerate() {
            let resp = send(
                &mut conn,
                &format!(
                    r#"{{"op":"unlearn","deployment":"{dep}","index":{idx}}}"#
                ),
            );
            assert_eq!(
                resp.get("ok").and_then(Json::as_bool),
                Some(true),
                "{dep} idx {idx}: {}",
                resp.encode()
            );
            assert_eq!(
                resp.get("n_train").and_then(Json::as_f64),
                Some((n - 1 - step) as f64),
                "{dep} idx {idx}"
            );
        }
        let fresh =
            Deployment::train_regression(dep, kind, &cfg, &reduced, None);
        let x = [0.3, -0.1, 0.2, 0.05];
        let want = fresh.predict_region(&x, 0.1, Some(1.0)).unwrap();
        let resp = send(
            &mut conn,
            &format!(
                r#"{{"op":"predict_region","deployment":"{dep}","x":[0.3,-0.1,0.2,0.05],"epsilon":0.1,"y":1.0}}"#
            ),
        );
        let ivs = resp
            .get("intervals")
            .unwrap_or_else(|| panic!("{dep}: {}", resp.encode()))
            .as_arr()
            .unwrap();
        assert_eq!(ivs.len(), want.region.intervals.len(), "{dep}");
        for (iv, w) in ivs.iter().zip(&want.region.intervals) {
            assert_eq!(iv.as_f64_vec().unwrap(), vec![w.lo, w.hi], "{dep}");
        }
        assert_eq!(
            resp.get("p_value").and_then(Json::as_f64),
            want.p_at_y,
            "{dep}"
        );
    }
    let bye = send(&mut conn, r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    handle.join().unwrap().unwrap();
}

/// Bad-index unlearns on regression deployments come back as structured
/// wire errors and increment the per-deployment unlearn error counter
/// (asserted through `op:"stats"`).
#[test]
fn regression_unlearn_errors_are_structured_and_counted() {
    let reg = mixed_registry(30);
    let server = Arc::new(Server::start(ServeConfig::default(), reg));
    // out-of-range index: structured error naming the bound
    let resp = server.handle(
        &Json::parse(r#"{"op":"unlearn","deployment":"rrcm","index":9999}"#)
            .unwrap(),
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    let msg = resp.get("error").and_then(Json::as_str).unwrap();
    assert!(
        msg.contains("out of range") && msg.contains("n_train"),
        "{msg}"
    );
    // missing index: structured error, counted globally but not against
    // a deployment (the request names none to charge it to)
    let resp = server.handle(
        &Json::parse(r#"{"op":"unlearn","deployment":"rrcm"}"#).unwrap(),
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    // a successful unlearn for contrast
    let resp = server.handle(
        &Json::parse(r#"{"op":"unlearn","deployment":"rrcm","index":0}"#)
            .unwrap(),
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(resp.get("n_train").and_then(Json::as_f64), Some(29.0));
    assert_eq!(resp.get("version").and_then(Json::as_f64), Some(1.0));
    // obs: the rrcm unlearn block saw 2 charged requests, 1 error
    let stats = server.handle(
        &Json::parse(r#"{"op":"stats","deployment":"rrcm"}"#).unwrap(),
    );
    let un = stats
        .get("deployments")
        .unwrap()
        .get("rrcm")
        .unwrap()
        .get("ops")
        .unwrap()
        .get("unlearn")
        .unwrap();
    assert_eq!(un.get("requests").and_then(Json::as_f64), Some(2.0));
    assert_eq!(un.get("errors").and_then(Json::as_f64), Some(1.0));
}

/// An unlearn riding in while a large predict_region batch is in
/// flight: the batcher reacquires the registry read lock every
/// LOCK_CHUNK = 16 jobs, so the unlearn's write lock waits for at most
/// one sub-chunk instead of the whole queue. Functionally: the unlearn
/// completes under load, and every concurrent answer equals either the
/// pre- or the post-unlearn reference exactly — never a torn state.
#[test]
fn unlearn_interleaved_with_inflight_predicts_is_exact() {
    let n = 40;
    let reg = mixed_registry(n);
    let x = [0.3, -0.1, 0.2, 0.05];
    let before = reg
        .with("reg", |d| d.predict_region(&x, 0.1, None))
        .unwrap()
        .unwrap();
    let mut reduced = make_regression(
        &RegressionSpec {
            n_samples: n,
            n_features: 4,
            n_informative: 3,
            noise: 3.0,
        },
        5,
    );
    reduced.remove(n - 1);
    let cfg = MeasureConfig {
        k: 3,
        ..Default::default()
    };
    let fresh = Deployment::train_regression(
        "reg",
        RegressorKind::Knn,
        &cfg,
        &reduced,
        None,
    );
    let after = fresh.predict_region(&x, 0.1, None).unwrap();
    let to_rows = |r: &exact_cp::coordinator::state::RegionAnswer| {
        r.region
            .intervals
            .iter()
            .map(|i| vec![i.lo, i.hi])
            .collect::<Vec<_>>()
    };
    let (pre, post) = (to_rows(&before), to_rows(&after));

    let server = Arc::new(Server::start(
        ServeConfig {
            workers: 1,
            max_batch: 16,
            max_wait_us: 2_000,
            ..Default::default()
        },
        reg,
    ));
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..32 {
            let srv = server.clone();
            handles.push(s.spawn(move || {
                let req = Json::parse(
                    r#"{"op":"predict_region","deployment":"reg","x":[0.3,-0.1,0.2,0.05],"epsilon":0.1}"#,
                )
                .unwrap();
                srv.handle(&req)
            }));
        }
        let srv = server.clone();
        let un = s.spawn(move || {
            let req = Json::parse(&format!(
                r#"{{"op":"unlearn","deployment":"reg","index":{}}}"#,
                n - 1
            ))
            .unwrap();
            srv.handle(&req)
        });
        let resp = un.join().unwrap();
        assert_eq!(
            resp.get("ok").and_then(Json::as_bool),
            Some(true),
            "{}",
            resp.encode()
        );
        assert_eq!(
            resp.get("n_train").and_then(Json::as_f64),
            Some((n - 1) as f64)
        );
        for h in handles {
            let resp = h.join().unwrap();
            let ivs = resp
                .get("intervals")
                .unwrap_or_else(|| panic!("{}", resp.encode()))
                .as_arr()
                .unwrap();
            let got: Vec<Vec<f64>> =
                ivs.iter().map(|iv| iv.as_f64_vec().unwrap()).collect();
            assert!(
                got == pre || got == post,
                "torn answer: {got:?} (pre {pre:?}, post {post:?})"
            );
        }
    });
}
