//! Observability end-to-end: tracing must never change served values
//! (the EXACTNESS.md contract), the validity monitor must track the
//! configured epsilons under labeled traffic, and the trace ring must
//! capture every pipeline stage.

use std::sync::{Arc, Mutex};

use exact_cp::config::{
    MeasureConfig, MeasureKind, ObsConfig, ServeConfig,
};
use exact_cp::coordinator::server::Server;
use exact_cp::coordinator::state::{Deployment, Registry};
use exact_cp::data::{make_classification, ClassificationSpec};
use exact_cp::obs::trace;
use exact_cp::util::json::Json;

/// Tests that flip the process-global trace switch serialize on this
/// lock (the ring and the enabled flag are shared process state).
static TRACE_GATE: Mutex<()> = Mutex::new(());

fn registry(n: usize) -> Arc<Registry> {
    let ds = make_classification(
        &ClassificationSpec {
            n_samples: n,
            ..Default::default()
        },
        1,
    );
    let reg = Arc::new(Registry::new());
    let cfg = MeasureConfig {
        k: 5,
        ..Default::default()
    };
    reg.insert(Deployment::train(
        "sknn",
        MeasureKind::SimplifiedKnn,
        &cfg,
        &ds,
        None,
    ));
    reg
}

fn predict_req(x: &[f64], y: Option<usize>, eps: f64) -> Json {
    let mut pairs = vec![
        ("op", Json::Str("predict".into())),
        ("deployment", Json::Str("sknn".into())),
        ("x", Json::from_f64_slice(x)),
        ("epsilon", Json::Num(eps)),
    ];
    if let Some(y) = y {
        pairs.push(("y", Json::Num(y as f64)));
    }
    Json::obj(pairs)
}

/// Acceptance gate: batch outputs are bit-identical with observability
/// on vs off. Two servers trained from the same seed serve the same
/// probes; every p-value must match to the bit.
#[test]
fn served_values_bit_identical_with_tracing_on() {
    let _g = TRACE_GATE.lock().unwrap();
    let probes: Vec<Vec<f64>> = (0..8)
        .map(|i| (0..30).map(|j| 0.05 * i as f64 - 0.01 * j as f64).collect())
        .collect();
    let collect = |srv: &Server| -> Vec<Vec<f64>> {
        probes
            .iter()
            .map(|x| {
                srv.handle(&predict_req(x, None, 0.1))
                    .get("p_values")
                    .unwrap()
                    .as_f64_vec()
                    .unwrap()
            })
            .collect()
    };

    trace::set_enabled(false);
    let srv_off = Server::start(
        ServeConfig {
            workers: 1,
            max_wait_us: 100,
            ..Default::default()
        },
        registry(80),
    );
    let base = collect(&srv_off);
    srv_off.shutdown();

    let srv_on = Server::start(
        ServeConfig {
            workers: 1,
            max_wait_us: 100,
            obs: ObsConfig {
                trace: true,
                ..Default::default()
            },
            ..Default::default()
        },
        registry(80),
    );
    assert!(trace::enabled(), "obs.trace must switch tracing on");
    let traced = collect(&srv_on);
    srv_on.shutdown();
    trace::set_enabled(false);

    assert_eq!(base.len(), traced.len());
    for (a, b) in base.iter().zip(&traced) {
        assert_eq!(a.len(), b.len());
        for (u, v) in a.iter().zip(b) {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "tracing changed a served p-value: {u} vs {v}"
            );
        }
    }
}

/// The ring captures every serving stage: queue wait, batch assembly,
/// the distance-kernel launch, scoring, p-value aggregation, and the
/// response isn't needed here since we bypass the socket.
#[test]
fn trace_ring_captures_pipeline_stages() {
    let _g = TRACE_GATE.lock().unwrap();
    let srv = Server::start(
        ServeConfig {
            workers: 1,
            max_wait_us: 100,
            obs: ObsConfig {
                trace: true,
                ..Default::default()
            },
            ..Default::default()
        },
        registry(60),
    );
    for i in 0..4 {
        let x: Vec<f64> = (0..30).map(|j| 0.02 * (i + j) as f64).collect();
        let resp = srv.handle(&predict_req(&x, None, 0.1));
        assert!(resp.get("p_values").is_some(), "{}", resp.encode());
    }
    let dump = srv.handle(
        &Json::parse(r#"{"op":"trace","limit":10000}"#).unwrap(),
    );
    srv.shutdown();
    trace::set_enabled(false);

    assert_eq!(dump.get("enabled").and_then(Json::as_bool), Some(true));
    let evs = dump.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!evs.is_empty());
    let names: std::collections::BTreeSet<&str> = evs
        .iter()
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    for want in [
        "queue_wait",
        "batch_assemble",
        "dist_kernel",
        "measure_scores",
        "p_value_agg",
    ] {
        assert!(names.contains(want), "missing stage {want}; saw {names:?}");
    }
    // every event is a complete ("X") span with sane fields
    for e in evs {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert!(e.get("ts").and_then(Json::as_f64).is_some());
        assert!(e.get("dur").and_then(Json::as_f64).is_some());
        assert!(e.get("args").and_then(|a| a.get("i")).is_some());
    }
}

/// Acceptance gate: labeled traffic drives the per-deployment validity
/// monitor, and the reported empirical error rate lands near each
/// tracked epsilon (conformal validity: P(error) <= eps, and for these
/// p-values approximately = eps on exchangeable data).
#[test]
fn labeled_traffic_error_rate_tracks_epsilon() {
    let train = make_classification(
        &ClassificationSpec {
            n_samples: 150,
            ..Default::default()
        },
        1,
    );
    // fresh draw from the same distribution => exchangeable probes
    let probe = make_classification(
        &ClassificationSpec {
            n_samples: 400,
            ..Default::default()
        },
        9,
    );
    let reg = Arc::new(Registry::new());
    reg.insert(Deployment::train(
        "sknn",
        MeasureKind::SimplifiedKnn,
        &MeasureConfig {
            k: 5,
            ..Default::default()
        },
        &train,
        None,
    ));
    let srv = Server::start(
        ServeConfig {
            workers: 2,
            max_wait_us: 100,
            obs: ObsConfig {
                epsilons: vec![0.2],
                ..Default::default()
            },
            ..Default::default()
        },
        reg,
    );
    for i in 0..probe.n() {
        let resp =
            srv.handle(&predict_req(probe.row(i), Some(probe.y[i]), 0.2));
        assert!(resp.get("p_values").is_some(), "{}", resp.encode());
    }
    let stats = srv
        .handle(&Json::parse(r#"{"op":"stats","deployment":"sknn"}"#).unwrap());
    srv.shutdown();

    let dep = stats.get("deployments").unwrap().get("sknn").unwrap();
    let validity = dep.get("validity").unwrap();
    let tracks = validity.get("per_epsilon").unwrap().as_arr().unwrap();
    assert_eq!(tracks.len(), 1);
    let t = &tracks[0];
    assert_eq!(t.get("epsilon").and_then(Json::as_f64), Some(0.2));
    assert_eq!(t.get("labeled").and_then(Json::as_f64), Some(400.0));
    let rate = t.get("error_rate").and_then(Json::as_f64).unwrap();
    // eps = 0.2, n = 400: sd ~ 0.02, so [0.08, 0.32] is a +-6 sd band
    assert!(
        (0.08..=0.32).contains(&rate),
        "error rate {rate} not near epsilon 0.2"
    );
    let sizes = t.get("mean_set_size").and_then(Json::as_f64).unwrap();
    assert!(sizes > 0.0 && sizes <= 2.0, "mean set size {sizes}");
    // histograms saw every labeled prediction
    let hist = validity.get("set_size_hist").unwrap();
    assert_eq!(hist.get("count").and_then(Json::as_f64), Some(400.0));
    let ph = validity.get("p_value_hist").unwrap();
    assert_eq!(ph.get("count").and_then(Json::as_f64), Some(400.0));
    // the per-op block counted the same traffic
    let predict = dep.get("ops").unwrap().get("predict").unwrap();
    assert_eq!(predict.get("requests").and_then(Json::as_f64), Some(400.0));
    assert_eq!(predict.get("errors").and_then(Json::as_f64), Some(0.0));
}
