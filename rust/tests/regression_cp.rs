//! Integration tests for full CP regression (§8): cross-method
//! behaviour, ridge vs k-NN, ICP comparisons, and the online extension.

use exact_cp::data::{make_regression, RegressionDataset, RegressionSpec, Rng};
use exact_cp::regression::{
    IcpKnnRegressor, KnnRegressorOptimized, KnnRegressorStandard, RidgeCp,
};

fn dataset(n: usize, noise: f64, seed: u64) -> RegressionDataset {
    make_regression(
        &RegressionSpec {
            n_samples: n,
            n_features: 10,
            n_informative: 5,
            noise,
        },
        seed,
    )
}

#[test]
fn ridge_beats_knn_on_linear_data() {
    // the generating model is linear, so ridge regions should be much
    // tighter than k-NN regions at the same eps
    let all = dataset(220, 5.0, 1);
    let mut rng = Rng::seed_from(2);
    let (train, test) = all.split(200, &mut rng);
    let mut ridge = RidgeCp::new(1.0);
    ridge.fit(&train);
    let mut knn = KnnRegressorOptimized::new(5);
    knn.fit(&train);
    let (mut w_ridge, mut w_knn) = (0.0, 0.0);
    for i in 0..test.n() {
        w_ridge += ridge
            .predict_region(test.row(i), 0.1)
            .hull()
            .map(|h| h.width())
            .unwrap_or(f64::INFINITY);
        w_knn += knn
            .predict_region(test.row(i), 0.1)
            .hull()
            .map(|h| h.width())
            .unwrap_or(f64::INFINITY);
    }
    assert!(
        w_ridge < w_knn,
        "ridge total width {w_ridge} should beat knn {w_knn} on linear data"
    );
}

#[test]
fn full_cp_interval_tighter_or_similar_to_icp() {
    // the paper: ICP has strictly weaker statistical power in regression
    // (Papadopoulos et al. 2011); full CP should not be (much) wider.
    let all = dataset(240, 20.0, 3);
    let mut rng = Rng::seed_from(4);
    let (train, test) = all.split(200, &mut rng);
    let mut full = KnnRegressorOptimized::new(5);
    full.fit(&train);
    let mut icp = IcpKnnRegressor::new(5);
    icp.fit(&train, 100);
    let (mut w_full, mut w_icp) = (0.0, 0.0);
    for i in 0..test.n() {
        w_full += full
            .predict_region(test.row(i), 0.2)
            .hull()
            .map(|h| h.width())
            .unwrap_or(f64::INFINITY);
        let (lo, hi) = icp.predict_interval(test.row(i), 0.2);
        w_icp += hi - lo;
    }
    assert!(
        w_full <= w_icp * 1.5,
        "full CP width {w_full} should be comparable to ICP {w_icp}"
    );
}

#[test]
fn narrower_region_at_larger_eps() {
    let all = dataset(150, 10.0, 5);
    let mut rng = Rng::seed_from(6);
    let (train, test) = all.split(130, &mut rng);
    let mut m = KnnRegressorOptimized::new(5);
    m.fit(&train);
    for i in 0..5 {
        let w10 = m
            .predict_region(test.row(i), 0.1)
            .hull()
            .map(|h| h.width())
            .unwrap_or(f64::INFINITY);
        let w30 = m
            .predict_region(test.row(i), 0.3)
            .hull()
            .map(|h| h.width())
            .unwrap_or(f64::INFINITY);
        assert!(
            w30 <= w10 + 1e-9,
            "region must shrink as eps grows: {w10} -> {w30}"
        );
    }
}

#[test]
fn online_learning_keeps_regions_exact() {
    // stream half the data via learn(); regions must equal a fresh fit
    let all = dataset(80, 8.0, 7);
    let first = RegressionDataset::new(
        all.x[..40 * all.p].to_vec(),
        all.y[..40].to_vec(),
        all.p,
    );
    let mut inc = KnnRegressorOptimized::new(4);
    inc.fit(&first);
    for i in 40..80 {
        inc.learn(all.row(i), all.y[i]);
    }
    let mut fresh = KnnRegressorOptimized::new(4);
    fresh.fit(&all);
    let probe = dataset(5, 8.0, 8);
    for i in 0..probe.n() {
        assert_eq!(
            inc.predict_region(probe.row(i), 0.1),
            fresh.predict_region(probe.row(i), 0.1)
        );
    }
}

#[test]
fn standard_and_optimized_pvalues_agree_on_probe_labels() {
    let train = dataset(60, 15.0, 9);
    let probe = dataset(5, 15.0, 10);
    let mut s = KnnRegressorStandard::new(3);
    let mut o = KnnRegressorOptimized::new(3);
    s.fit(&train);
    o.fit(&train);
    for i in 0..probe.n() {
        for y in [-100.0, 0.0, probe.y[i], 500.0] {
            assert_eq!(
                s.p_value(probe.row(i), y),
                o.p_value(probe.row(i), y),
                "i={i} y={y}"
            );
        }
    }
}
