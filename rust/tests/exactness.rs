//! THE central test suite: the paper's claim is *exact* optimization —
//! optimized full CP must produce the SAME p-values as standard full CP
//! for k-NN, Simplified k-NN, KDE, and kernel LS-SVM (Table 1 ✓ rows),
//! and the optimized k-NN CP regressor must produce the same prediction
//! regions as the Papadopoulos et al. (2011) method.

use exact_cp::config::{MeasureConfig, MeasureKind};
use exact_cp::coordinator::factory::{build_measure, build_standard_measure};
use exact_cp::cp::pvalue::p_value;
use exact_cp::data::{
    make_classification, make_regression, ClassificationSpec, Dataset,
    RegressionSpec, Rng,
};
use exact_cp::regression::{KnnRegressorOptimized, KnnRegressorStandard};

fn ds(n: usize, p: usize, seed: u64) -> Dataset {
    make_classification(
        &ClassificationSpec {
            n_samples: n,
            n_features: p,
            n_informative: p.min(4),
            n_redundant: 0,
            ..Default::default()
        },
        seed,
    )
}

/// p-value agreement for one measure kind over a grid of datasets.
fn assert_exact(kind: MeasureKind, k: usize, tol: f64) {
    let cfg = MeasureConfig {
        k,
        b: 5,
        ..Default::default()
    };
    for (n, p, seed) in [(20, 5, 1u64), (45, 8, 2), (31, 3, 3)] {
        let train = ds(n, p, seed);
        let probe = ds(7, p, seed + 100);
        let mut std_m = build_standard_measure(kind, &cfg);
        let mut opt_m = build_measure(kind, &cfg, None);
        std_m.fit(&train);
        opt_m.fit(&train);
        for i in 0..probe.n() {
            for y in 0..train.n_labels {
                let ps = p_value(&std_m.scores(probe.row(i), y));
                let po = p_value(&opt_m.scores(probe.row(i), y));
                assert!(
                    (ps - po).abs() <= tol,
                    "{kind:?} n={n} p={p} seed={seed} i={i} y={y}: {ps} vs {po}"
                );
            }
        }
    }
}

#[test]
fn simplified_knn_pvalues_exact() {
    assert_exact(MeasureKind::SimplifiedKnn, 3, 0.0);
    assert_exact(MeasureKind::SimplifiedKnn, 15, 0.0); // k > class sizes
}

#[test]
fn knn_pvalues_exact() {
    assert_exact(MeasureKind::Knn, 3, 0.0);
    assert_exact(MeasureKind::Knn, 1, 0.0); // NN measure (Eq. 1)
}

#[test]
fn kde_pvalues_exact() {
    assert_exact(MeasureKind::Kde, 15, 0.0);
}

#[test]
fn lssvm_pvalues_exact() {
    // float round-off only: rank-1 updates vs refactorization; ties in
    // continuous scores have measure zero, so p-values agree exactly in
    // practice — assert identical.
    assert_exact(MeasureKind::LsSvm, 15, 0.0);
}

#[test]
fn exactness_survives_online_updates() {
    // optimized measure, after a learn+unlearn churn, must still equal
    // the standard measure fitted on the final dataset.
    let cfg = MeasureConfig {
        k: 4,
        ..Default::default()
    };
    let base = ds(30, 6, 10);
    let extra = ds(8, 6, 11);
    let mut opt_m = build_measure(MeasureKind::SimplifiedKnn, &cfg, None);
    opt_m.fit(&base);
    let mut final_ds = base.clone();
    for i in 0..extra.n() {
        assert!(opt_m.learn(extra.row(i), extra.y[i]));
        final_ds.push(extra.row(i), extra.y[i]);
    }
    // remove three points, including one of the freshly learned ones
    for idx in [33, 12, 0] {
        assert!(opt_m.unlearn(idx));
        final_ds.remove(idx);
    }
    let mut std_m = build_standard_measure(MeasureKind::SimplifiedKnn, &cfg);
    std_m.fit(&final_ds);
    let probe = ds(5, 6, 12);
    for i in 0..probe.n() {
        for y in 0..2 {
            let ps = p_value(&std_m.scores(probe.row(i), y));
            let po = p_value(&opt_m.scores(probe.row(i), y));
            assert_eq!(ps, po, "after churn: i={i} y={y}");
        }
    }
}

#[test]
fn knn_regression_regions_exact() {
    for seed in 0..3u64 {
        let d = make_regression(
            &RegressionSpec {
                n_samples: 40,
                n_features: 6,
                n_informative: 3,
                noise: 3.0,
            },
            seed,
        );
        let probe = make_regression(
            &RegressionSpec {
                n_samples: 6,
                n_features: 6,
                n_informative: 3,
                noise: 3.0,
            },
            seed + 50,
        );
        let mut s = KnnRegressorStandard::new(4);
        let mut o = KnnRegressorOptimized::new(4);
        s.fit(&d);
        o.fit(&d);
        for i in 0..probe.n() {
            for eps in [0.05, 0.1, 0.25] {
                assert_eq!(
                    s.predict_region(probe.row(i), eps),
                    o.predict_region(probe.row(i), eps),
                    "seed={seed} i={i} eps={eps}"
                );
            }
        }
    }
}

#[test]
fn exactness_on_degenerate_data() {
    // all-duplicate points, single-class-dominated labels, zero variance
    let mut x = vec![1.0; 20 * 3];
    x[3] = 2.0; // one point differs slightly
    let mut y = vec![0usize; 20];
    y[19] = 1; // single example of class 1
    let train = Dataset::new(x, y, 3, 2);
    let cfg = MeasureConfig {
        k: 3,
        ..Default::default()
    };
    for kind in [MeasureKind::SimplifiedKnn, MeasureKind::Knn, MeasureKind::Kde] {
        let mut s = build_standard_measure(kind, &cfg);
        let mut o = build_measure(kind, &cfg, None);
        s.fit(&train);
        o.fit(&train);
        for probe in [[1.0, 1.0, 1.0], [9.0, 9.0, 9.0]] {
            for yy in 0..2 {
                let ps = p_value(&s.scores(&probe, yy));
                let po = p_value(&o.scores(&probe, yy));
                assert_eq!(ps, po, "{kind:?} probe={probe:?} y={yy}");
            }
        }
    }
}

#[test]
fn randomized_exactness_sweep() {
    // 25 random configurations per measure — an in-tree property-based
    // harness (the offline environment ships no proptest; see
    // rust/tests/proptests.rs for the shrinking variant).
    let mut rng = Rng::seed_from(999);
    for trial in 0..25 {
        let n = 10 + rng.below(40);
        let p = 2 + rng.below(6);
        let k = 1 + rng.below(6);
        let seed = rng.next_u64() % 10_000;
        let train = ds(n, p, seed);
        let probe = ds(3, p, seed + 1);
        let cfg = MeasureConfig {
            k,
            ..Default::default()
        };
        for kind in [MeasureKind::SimplifiedKnn, MeasureKind::Knn, MeasureKind::Kde]
        {
            let mut s = build_standard_measure(kind, &cfg);
            let mut o = build_measure(kind, &cfg, None);
            s.fit(&train);
            o.fit(&train);
            for i in 0..probe.n() {
                for y in 0..train.n_labels {
                    let ps = p_value(&s.scores(probe.row(i), y));
                    let po = p_value(&o.scores(probe.row(i), y));
                    assert_eq!(
                        ps, po,
                        "trial={trial} {kind:?} n={n} p={p} k={k} seed={seed}"
                    );
                }
            }
        }
    }
}
