//! Property-based tests with an in-tree generator + shrinking harness
//! (the offline environment ships no proptest crate). `check` runs a
//! property over N random cases; on failure it greedily shrinks n, p,
//! and k before reporting, so failures are minimal-ish and the failing
//! seed is printed for replay.

use exact_cp::config::{MeasureConfig, MeasureKind};
use exact_cp::coordinator::factory::{build_measure, build_standard_measure};
use exact_cp::cp::pvalue::p_value;
use exact_cp::data::{
    make_classification, make_regression, ClassificationSpec, Dataset,
    RegressionDataset, RegressionSpec, Rng,
};
use exact_cp::linalg::select::KBest;
use exact_cp::regression::region::ge_set;
use exact_cp::regression::{
    conformal_region, p_value_at, Coefficients, CpRegressor,
    KnnRegressorOptimized, KnnRegressorStandard, RidgeCp,
};

/// One randomized case of the measure-exactness property.
#[derive(Clone, Copy, Debug)]
struct Case {
    n: usize,
    p: usize,
    k: usize,
    seed: u64,
}

fn gen_case(rng: &mut Rng) -> Case {
    Case {
        n: 8 + rng.below(50),
        p: 1 + rng.below(8),
        k: 1 + rng.below(8),
        seed: rng.next_u64() % 100_000,
    }
}

fn shrink(case: Case) -> Vec<Case> {
    let mut out = Vec::new();
    if case.n > 8 {
        out.push(Case {
            n: (case.n / 2).max(8),
            ..case
        });
    }
    if case.p > 1 {
        out.push(Case {
            p: case.p / 2,
            ..case
        });
    }
    if case.k > 1 {
        out.push(Case {
            k: case.k / 2,
            ..case
        });
    }
    out
}

fn check(name: &str, cases: usize, prop: impl Fn(Case) -> bool) {
    let mut rng = Rng::seed_from(0xC0FFEE);
    for _ in 0..cases {
        let case = gen_case(&mut rng);
        if !prop(case) {
            // greedy shrink
            let mut minimal = case;
            loop {
                let mut shrunk = false;
                for cand in shrink(minimal) {
                    if !prop(cand) {
                        minimal = cand;
                        shrunk = true;
                        break;
                    }
                }
                if !shrunk {
                    break;
                }
            }
            panic!("property {name} failed; minimal case: {minimal:?}");
        }
    }
}

fn dataset(c: Case) -> Dataset {
    make_classification(
        &ClassificationSpec {
            n_samples: c.n,
            n_features: c.p,
            n_informative: c.p.min(3),
            n_redundant: 0,
            ..Default::default()
        },
        c.seed,
    )
}

#[test]
fn prop_optimized_equals_standard_nn_family() {
    check("nn-exactness", 40, |c| {
        let train = dataset(c);
        let probe = dataset(Case {
            n: 8,
            seed: c.seed + 1,
            ..c
        });
        let cfg = MeasureConfig {
            k: c.k,
            ..Default::default()
        };
        for kind in [MeasureKind::SimplifiedKnn, MeasureKind::Knn] {
            let mut s = build_standard_measure(kind, &cfg);
            let mut o = build_measure(kind, &cfg, None);
            s.fit(&train);
            o.fit(&train);
            for i in 0..3 {
                for y in 0..train.n_labels {
                    if p_value(&s.scores(probe.row(i), y))
                        != p_value(&o.scores(probe.row(i), y))
                    {
                        return false;
                    }
                }
            }
        }
        true
    });
}

/// Bit-for-bit equality of one `Scores` pair.
fn scores_identical(
    a: &exact_cp::cp::measure::Scores,
    b: &exact_cp::cp::measure::Scores,
) -> bool {
    a.train.len() == b.train.len()
        && a.test.to_bits() == b.test.to_bits()
        && a.train
            .iter()
            .zip(&b.train)
            .all(|(u, v)| u.to_bits() == v.to_bits())
}

#[test]
fn prop_scores_batch_equals_per_pair_bitwise() {
    // THE batch contract: for every measure kind, optimized AND
    // standard variants, scores_batch over random (xs, labels) equals
    // the per-pair scores() cross product bit for bit.
    check("batch-vs-single", 12, |c| {
        let train = dataset(c);
        let probe = dataset(Case {
            n: 8,
            seed: c.seed + 9,
            ..c
        });
        let cfg = MeasureConfig {
            k: c.k,
            b: 2,
            ..Default::default()
        };
        let labels: Vec<usize> = (0..train.n_labels).collect();
        for kind in MeasureKind::all() {
            for standard in [false, true] {
                let mut m = if standard {
                    build_standard_measure(kind, &cfg)
                } else {
                    build_measure(kind, &cfg, None)
                };
                m.fit(&train);
                // the standard RF baseline retrains B(n+1) trees per
                // pair — keep its batch small so the property stays fast
                let n_probe = if kind == MeasureKind::RandomForest && standard
                {
                    2
                } else {
                    probe.n()
                };
                let xs: Vec<&[f64]> =
                    (0..n_probe).map(|i| probe.row(i)).collect();
                let batch = m.scores_batch(&xs, &labels);
                if batch.len() != xs.len() * labels.len() {
                    return false;
                }
                for (xi, x) in xs.iter().enumerate() {
                    for (li, &y) in labels.iter().enumerate() {
                        let single = m.scores(x, y);
                        if !scores_identical(
                            &batch[xi * labels.len() + li],
                            &single,
                        ) {
                            return false;
                        }
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_scores_batch_edge_cases() {
    // empty batch, empty label set, and single-pair batches must all
    // behave for every measure kind and variant
    let train = dataset(Case {
        n: 14,
        p: 4,
        k: 3,
        seed: 77,
    });
    let probe = dataset(Case {
        n: 2,
        p: 4,
        k: 3,
        seed: 78,
    });
    let cfg = MeasureConfig {
        k: 3,
        b: 2,
        ..Default::default()
    };
    let labels: Vec<usize> = (0..train.n_labels).collect();
    for kind in MeasureKind::all() {
        for standard in [false, true] {
            let mut m = if standard {
                build_standard_measure(kind, &cfg)
            } else {
                build_measure(kind, &cfg, None)
            };
            m.fit(&train);
            assert!(
                m.scores_batch(&[], &labels).is_empty(),
                "{kind:?} standard={standard}: empty xs"
            );
            let xs: Vec<&[f64]> = vec![probe.row(0)];
            assert!(
                m.scores_batch(&xs, &[]).is_empty(),
                "{kind:?} standard={standard}: empty labels"
            );
            let one = m.scores_batch(&xs, &[1]);
            assert_eq!(one.len(), 1);
            assert!(
                scores_identical(&one[0], &m.scores(probe.row(0), 1)),
                "{kind:?} standard={standard}: single pair"
            );
        }
    }
}

#[test]
fn prop_pvalues_in_valid_range() {
    // p in [1/(n+1), 1] for every measure and candidate label
    check("pvalue-range", 30, |c| {
        let train = dataset(c);
        let probe = dataset(Case {
            n: 4,
            seed: c.seed + 2,
            ..c
        });
        let cfg = MeasureConfig {
            k: c.k,
            b: 4,
            ..Default::default()
        };
        for kind in [
            MeasureKind::SimplifiedKnn,
            MeasureKind::Kde,
            MeasureKind::RandomForest,
        ] {
            let mut m = build_measure(kind, &cfg, None);
            m.fit(&train);
            let lo = 1.0 / (train.n() + 1) as f64;
            for i in 0..2 {
                for y in 0..train.n_labels {
                    let p = p_value(&m.scores(probe.row(i), y));
                    if !(lo - 1e-12..=1.0 + 1e-12).contains(&p) {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_learn_unlearn_roundtrip_is_identity() {
    // Learning a point then unlearning it restores all p-values for
    // every classification measure that supports decremental updates.
    //
    // Tolerance, not bitwise: classification measures maintain their
    // state incrementally in *insertion order* (KBest running sums,
    // KDE's `prelim -= k` subtraction, LS-SVM rank-1 downdates), so an
    // unlearn is algebraically — but not FP-bitwise — the inverse of a
    // learn. Only the regression side replays sums in canonical order
    // and therefore promises bit identity (see EXACTNESS.md
    // "Decremental paths" and the prop_regressor_* tests below).
    check("learn-unlearn-identity", 25, |c| {
        let train = dataset(c);
        let probe = dataset(Case {
            n: 3,
            seed: c.seed + 3,
            ..c
        });
        let cfg = MeasureConfig {
            k: c.k,
            ..Default::default()
        };
        for kind in [
            MeasureKind::SimplifiedKnn,
            MeasureKind::Knn,
            MeasureKind::Kde,
            MeasureKind::LsSvm,
        ] {
            let mut m = build_measure(kind, &cfg, None);
            m.fit(&train);
            let before: Vec<f64> = (0..probe.n())
                .flat_map(|i| {
                    (0..train.n_labels)
                        .map(|y| p_value(&m.scores(probe.row(i), y)))
                        .collect::<Vec<_>>()
                })
                .collect();
            let x_new = probe.row(0).to_vec();
            if !m.learn(&x_new, 0) || !m.unlearn(train.n()) {
                return false;
            }
            let after: Vec<f64> = (0..probe.n())
                .flat_map(|i| {
                    (0..train.n_labels)
                        .map(|y| p_value(&m.scores(probe.row(i), y)))
                        .collect::<Vec<_>>()
                })
                .collect();
            // 1e-8 matches the per-measure online tests (LS-SVM's
            // rank-1 downdate is the least precise of the family)
            if before
                .iter()
                .zip(&after)
                .any(|(a, b)| (a - b).abs() > 1e-8)
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_kbest_invariants() {
    // KBest is always sorted, bounded by k, sum-consistent
    let mut rng = Rng::seed_from(0xBEEF);
    for _ in 0..200 {
        let k = 1 + rng.below(10);
        let mut kb = KBest::new(k);
        let mut all: Vec<f64> = Vec::new();
        for _ in 0..rng.below(40) {
            let v = rng.f64() * 100.0;
            kb.insert(v);
            all.push(v);
        }
        assert!(kb.len() <= k);
        let vals = kb.values();
        assert!(vals.windows(2).all(|w| w[0] <= w[1]), "sorted");
        let sum: f64 = vals.iter().sum();
        assert!((kb.sum() - sum).abs() < 1e-9, "sum consistent");
        all.sort_by(|a, b| a.total_cmp(b));
        let want: Vec<f64> = all.into_iter().take(k).collect();
        assert_eq!(vals, &want[..], "holds the k smallest");
    }
}

#[test]
fn prop_region_primitive_invariants() {
    // structural invariants of the exact-region machinery on random
    // affine score systems, including degenerate b_i = 0 rays and
    // near-parallel (b_i ~ b) pairs:
    //   ge_set:         at most 2 intervals, each non-empty, pointwise
    //                   equal to |a_i + b_i y| >= |a + b y|
    //   conformal_region: intervals sorted, pairwise disjoint (touching
    //                   ones merged), p_value_at(y) > eps <=> contains(y)
    let mut rng = Rng::seed_from(0x5EED);
    for _ in 0..80 {
        let n = 3 + rng.below(30);
        let coefs: Vec<(f64, f64)> = (0..n)
            .map(|_| {
                (
                    rng.normal() * 4.0,
                    match rng.below(4) {
                        0 => 0.0, // kNN-style degenerate ray
                        1 => -1.0 / (1.0 + rng.below(5) as f64),
                        2 => 1.0 + rng.normal() * 1e-9, // ~parallel to test
                        _ => rng.normal() * 0.8,
                    },
                )
            })
            .collect();
        let a = rng.normal() * 2.0;
        let b = match rng.below(3) {
            0 => 1.0,
            1 => -1.0,
            _ => 0.5 + rng.f64(),
        };
        for &(ai, bi) in &coefs {
            let set = ge_set(ai, bi, a, b);
            assert!(set.len() <= 2, "ge_set returned {set:?}");
            for iv in &set {
                assert!(iv.lo <= iv.hi, "empty interval {iv:?}");
            }
            for _ in 0..8 {
                let y = rng.normal() * 6.0;
                let margin = (ai + bi * y).abs() - (a + b * y).abs();
                if margin.abs() < 1e-9 {
                    continue; // too close to a critical point to judge
                }
                let got = set.iter().any(|iv| iv.contains(y));
                assert_eq!(
                    got,
                    margin >= 0.0,
                    "ge_set({ai},{bi},{a},{b}) at y={y}: {set:?}"
                );
            }
        }
        let eps = 0.02 + rng.f64() * 0.6;
        let region = conformal_region(&coefs, a, b, eps);
        for iv in &region.intervals {
            assert!(iv.lo <= iv.hi, "empty interval in {region:?}");
        }
        for w in region.intervals.windows(2) {
            assert!(
                w[0].hi < w[1].lo,
                "intervals must be sorted and disjoint: {region:?}"
            );
        }
        for _ in 0..20 {
            let y = rng.normal() * 8.0;
            let near_crit = coefs.iter().any(|&(ai, bi)| {
                ((ai + bi * y).abs() - (a + b * y).abs()).abs() < 1e-7
            });
            if near_crit {
                continue;
            }
            assert_eq!(
                region.contains(y),
                p_value_at(&coefs, a, b, y) > eps,
                "n={n} a={a} b={b} eps={eps} y={y} region={region:?}"
            );
        }
    }
}

/// Bit-for-bit equality of one regression `Coefficients` triple.
fn coefs_identical(u: &Coefficients, v: &Coefficients) -> bool {
    u.1.to_bits() == v.1.to_bits()
        && u.2.to_bits() == v.2.to_bits()
        && u.0.len() == v.0.len()
        && u.0
            .iter()
            .zip(&v.0)
            .all(|(x, y)| x.0.to_bits() == y.0.to_bits() && x.1.to_bits() == y.1.to_bits())
}

fn reg_dataset(n: usize, p: usize, seed: u64) -> RegressionDataset {
    make_regression(
        &RegressionSpec {
            n_samples: n,
            n_features: p,
            n_informative: p.min(3),
            noise: 4.0,
        },
        seed,
    )
}

#[test]
fn prop_regression_batch_equals_per_object_bitwise() {
    // THE regression batch contract: for both kNN variants and ridge,
    // coefficients_batch / predict_region_batch / p_values_batch over a
    // random probe set (with duplicated probes and a probe equal to a
    // training row) match the per-object path bit for bit — on the raw
    // dataset AND on a quantized-label copy full of duplicate y values.
    check("reg-batch-vs-single", 15, |c| {
        let train = reg_dataset(c.n, c.p, c.seed);
        let probe = reg_dataset(6, c.p, c.seed + 1);
        let mut xs: Vec<&[f64]> = (0..probe.n()).map(|i| probe.row(i)).collect();
        xs.push(probe.row(0)); // duplicate probe
        xs.push(train.row(c.n / 2)); // probe identical to a training row
        let k = c.k.min(c.n - 1).max(1);
        let mut quant = train.clone();
        for y in quant.y.iter_mut() {
            *y = (*y / 10.0).round() * 10.0; // duplicate-y edge case
        }
        for ds in [&train, &quant] {
            let mut s = KnnRegressorStandard::new(k);
            let mut o = KnnRegressorOptimized::new(k);
            let mut r = RidgeCp::new(1.0);
            s.fit(ds);
            o.fit(ds);
            r.fit(ds);
            let regs: [&dyn CpRegressor; 3] = [&s, &o, &r];
            for m in regs {
                let batch = m.coefficients_batch(&xs);
                if batch.len() != xs.len() {
                    return false;
                }
                for (got, &x) in batch.iter().zip(&xs) {
                    if !coefs_identical(got, &m.coefficients(x)) {
                        return false;
                    }
                }
                // empty and singleton batches
                if !m.coefficients_batch(&[]).is_empty() {
                    return false;
                }
                let one = m.coefficients_batch(&xs[..1]);
                if one.len() != 1 || !coefs_identical(&one[0], &m.coefficients(xs[0])) {
                    return false;
                }
                // regions and p-values ride on the same coefficients,
                // so they must agree exactly too
                let regions = m.predict_region_batch(&xs, 0.1);
                for (got, &x) in regions.iter().zip(&xs) {
                    if *got != m.predict_region(x, 0.1) {
                        return false;
                    }
                }
                let ys: Vec<f64> =
                    (0..xs.len()).map(|i| ds.y[i % ds.n()]).collect();
                let ps = m.p_values_batch(&xs, &ys);
                for (i, &x) in xs.iter().enumerate() {
                    if ps[i].to_bits() != m.p_value(x, ys[i]).to_bits() {
                        return false;
                    }
                }
            }
        }
        true
    });
}

fn gaussian_flat(rng: &mut Rng, len: usize) -> Vec<f64> {
    (0..len).map(|_| rng.normal() * 3.0).collect()
}

/// Reference path: one `dist_row_sq_into` call per test row, stacked.
fn stacked_rows(xs: &[f64], rows: &[f64], p: usize) -> Vec<f64> {
    let (m, n) = (xs.len() / p, rows.len() / p);
    let mut out = vec![0.0; m * n];
    for (x, o) in xs.chunks_exact(p).zip(out.chunks_exact_mut(n)) {
        exact_cp::linalg::dist_row_sq_into(x, rows, p, o);
    }
    out
}

fn bits_equal(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_dist_matrix_equals_stacked_rows_bitwise() {
    // THE tiled-kernel contract: the m x n matrix path replays the
    // per-row op order exactly, so every entry is bit-identical to the
    // stacked dist_row_sq_into reference — on random shapes AND the
    // named edge shapes (empty batch, single row, odd p, m >> n, n >> m).
    check("dist-matrix-vs-rows", 30, |c| {
        let mut rng = Rng::seed_from(c.seed);
        let (m, p) = (c.k, c.p); // reuse the case's k as the batch size
        let xs = gaussian_flat(&mut rng, m * p);
        let rows = gaussian_flat(&mut rng, c.n * p);
        let mut got = vec![0.0; m * c.n];
        exact_cp::linalg::dist_matrix_sq_into(&xs, &rows, p, &mut got);
        bits_equal(&got, &stacked_rows(&xs, &rows, p))
    });
    let mut rng = Rng::seed_from(0xD157);
    for (m, n, p) in [
        (0, 12, 3),   // empty test batch
        (5, 0, 3),    // empty training set
        (1, 17, 5),   // single test row
        (3, 9, 1),    // p = 1 (pure scalar tail)
        (7, 11, 3),   // odd everything
        (64, 2, 5),   // m >> n
        (2, 300, 5),  // n >> m (multiple L1 blocks at larger p)
        (9, 700, 3),  // tail rows + several training blocks
    ] {
        let xs = gaussian_flat(&mut rng, m * p);
        let rows = gaussian_flat(&mut rng, n * p);
        let mut got = vec![0.0; m * n];
        exact_cp::linalg::dist_matrix_sq_into(&xs, &rows, p, &mut got);
        assert!(
            bits_equal(&got, &stacked_rows(&xs, &rows, p)),
            "edge shape m={m} n={n} p={p}"
        );
    }
}

#[test]
fn prop_dist_matrix_workers_identical_bytes() {
    // determinism contract: the scoped-parallel path partitions output
    // tiles but never changes a value, so bytes match the serial kernel
    // for every worker count
    check("dist-matrix-workers", 20, |c| {
        let mut rng = Rng::seed_from(c.seed ^ 0x50_CA1);
        let (m, p) = (c.k + 7, c.p); // span multiple PAR_TILE_M jobs
        let xs = gaussian_flat(&mut rng, m * p);
        let rows = gaussian_flat(&mut rng, c.n * p);
        let mut serial = vec![0.0; m * c.n];
        exact_cp::linalg::dist_matrix_sq_into(&xs, &rows, p, &mut serial);
        [1usize, 2, 4].into_iter().all(|w| {
            let mut par = vec![0.0; m * c.n];
            exact_cp::linalg::dist_matrix_sq_into_workers(
                &xs, &rows, p, w, &mut par,
            );
            bits_equal(&par, &serial)
        })
    });
}

#[test]
fn prop_pairwise_sq_matches_matrix_kernel() {
    // pairwise_sq rides the tiled kernel and mirrors the upper triangle;
    // it must stay bitwise-consistent with the full-matrix path and keep
    // an exactly-zero diagonal
    check("pairwise-vs-matrix", 20, |c| {
        let mut rng = Rng::seed_from(c.seed + 13);
        let a = gaussian_flat(&mut rng, c.n * c.p);
        let got = exact_cp::linalg::pairwise_sq(&a, c.p);
        let full = exact_cp::linalg::dist_matrix_sq(&a, &a, c.p);
        (0..c.n).all(|i| {
            got[i * c.n + i].to_bits() == 0.0f64.to_bits()
                && (0..c.n).all(|j| {
                    i == j
                        || got[i * c.n + j].to_bits()
                            == full[i * c.n + j].to_bits()
                })
        })
    });
}

#[test]
fn prop_region_sweep_equals_direct_pvalue() {
    // conformal_region == pointwise p_value_at thresholding, on random
    // affine-coefficient systems (away from critical points)
    let mut rng = Rng::seed_from(0xABCD);
    for _ in 0..60 {
        let n = 4 + rng.below(40);
        let coefs: Vec<(f64, f64)> = (0..n)
            .map(|_| {
                (
                    rng.normal() * 4.0,
                    match rng.below(3) {
                        0 => 0.0,
                        1 => -1.0 / (1.0 + rng.below(5) as f64),
                        _ => rng.normal() * 0.5,
                    },
                )
            })
            .collect();
        let a = rng.normal() * 2.0;
        let eps = 0.02 + rng.f64() * 0.6;
        let region = conformal_region(&coefs, a, 1.0, eps);
        for _ in 0..30 {
            let y = rng.normal() * 8.0;
            let near_crit = coefs
                .iter()
                .any(|&(ai, bi)| ((ai + bi * y).abs() - (a + y).abs()).abs() < 1e-7);
            if near_crit {
                continue;
            }
            let want = p_value_at(&coefs, a, 1.0, y) > eps;
            assert_eq!(
                region.contains(y),
                want,
                "n={n} a={a} eps={eps} y={y} region={region:?}"
            );
        }
    }
}

/// One fresh (unfitted) regressor of each kind, in a fixed order.
fn fresh_regressors(k: usize) -> Vec<Box<dyn CpRegressor>> {
    vec![
        Box::new(KnnRegressorStandard::new(k)),
        Box::new(KnnRegressorOptimized::new(k)),
        Box::new(RidgeCp::new(1.0)),
    ]
}

#[test]
fn prop_regressor_learn_unlearn_roundtrip_bitwise() {
    // THE decremental contract, identity half: for every regressor kind
    // learn(z) followed by unlearn(last) restores the coefficients BIT
    // FOR BIT — the ridge journal and the canonical-order neighbour
    // statistics replay the exact FP op sequence of the original fit.
    // Repeated rounds catch state leaking across the round trip.
    check("reg-learn-unlearn-roundtrip", 12, |c| {
        let train = reg_dataset(c.n, c.p, c.seed);
        let probe = reg_dataset(4, c.p, c.seed + 7);
        let xs: Vec<&[f64]> = (0..probe.n()).map(|i| probe.row(i)).collect();
        let k = c.k.min(c.n - 1).max(1);
        for mut m in fresh_regressors(k) {
            m.fit(&train);
            let before: Vec<Coefficients> =
                xs.iter().map(|x| m.coefficients(x)).collect();
            let z = probe.row(0).to_vec();
            for _ in 0..3 {
                if !m.learn(&z, 1.25) || !m.unlearn(train.n()) {
                    return false;
                }
            }
            if m.n() != train.n() {
                return false;
            }
            for (x, want) in xs.iter().zip(&before) {
                if !coefs_identical(&m.coefficients(x), want) {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_regressor_unlearn_matches_fresh_fit_bitwise() {
    // THE decremental contract, refit half: after each unlearn(idx) the
    // live regressor serves coefficients bit-identical to a fresh fit
    // on the reduced training set — at the edge indices (last, first,
    // middle) applied in sequence, for every regressor kind. Out-of-
    // range unlearns must be rejected without mutating state.
    check("reg-unlearn-vs-fresh", 10, |c| {
        let train = reg_dataset(c.n, c.p, c.seed);
        let probe = reg_dataset(3, c.p, c.seed + 11);
        let xs: Vec<&[f64]> = (0..probe.n()).map(|i| probe.row(i)).collect();
        // three removals shrink n by 3; keep k valid for the smallest set
        let k = c.k.min(c.n.saturating_sub(4)).max(1);
        let idxs = [c.n - 1, 0, (c.n - 2) / 2];
        for mi in 0..3 {
            let mut live = fresh_regressors(k).swap_remove(mi);
            live.fit(&train);
            let mut reduced = train.clone();
            for &idx in &idxs {
                if !live.unlearn(idx) {
                    return false;
                }
                reduced.remove(idx);
                let mut fresh = fresh_regressors(k).swap_remove(mi);
                fresh.fit(&reduced);
                for x in &xs {
                    if !coefs_identical(
                        &live.coefficients(x),
                        &fresh.coefficients(x),
                    ) {
                        return false;
                    }
                }
            }
            if live.unlearn(reduced.n()) {
                return false; // out of range must be rejected
            }
            if live.n() != reduced.n() {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_regressor_interleaved_online_matches_fresh_fit() {
    // Random interleavings of learn and unlearn (including repeated
    // removals at index 0) track a mirror dataset; after every step the
    // live regressor must serve bit-identically to a fresh fit on the
    // mirror. This is the serving coordinator's actual op sequence.
    check("reg-interleaved-online", 8, |c| {
        let train = reg_dataset(c.n, c.p, c.seed);
        let probe = reg_dataset(3, c.p, c.seed + 13);
        let xs: Vec<&[f64]> = (0..probe.n()).map(|i| probe.row(i)).collect();
        let k = c.k.min(c.n.saturating_sub(4)).max(1);
        let mut rng = Rng::seed_from(c.seed ^ 0xD1CE);
        for mi in 0..3 {
            let mut live = fresh_regressors(k).swap_remove(mi);
            live.fit(&train);
            let mut mirror = train.clone();
            for step in 0..6 {
                if rng.below(2) == 0 || mirror.n() <= k + 1 {
                    let x: Vec<f64> =
                        (0..c.p).map(|_| rng.normal() * 2.0).collect();
                    let y = rng.normal() * 5.0;
                    if !live.learn(&x, y) {
                        return false;
                    }
                    mirror.push(&x, y);
                } else {
                    // bias towards the edges: 0, last, then random
                    let idx = match step % 3 {
                        0 => 0,
                        1 => mirror.n() - 1,
                        _ => rng.below(mirror.n()),
                    };
                    if !live.unlearn(idx) {
                        return false;
                    }
                    mirror.remove(idx);
                }
                let mut fresh = fresh_regressors(k).swap_remove(mi);
                fresh.fit(&mirror);
                for x in &xs {
                    if !coefs_identical(
                        &live.coefficients(x),
                        &fresh.coefficients(x),
                    ) {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_measure_unlearn_matches_fresh_fit() {
    // Classification counterpart of reg-unlearn-vs-fresh at the
    // documented tolerance (see prop_learn_unlearn_roundtrip_is_identity
    // for why classification is not bitwise): unlearning the first and
    // last training examples must track a fresh fit on the reduced set.
    check("measure-unlearn-vs-fresh", 10, |c| {
        let train = dataset(c);
        let probe = dataset(Case {
            n: 3,
            seed: c.seed + 17,
            ..c
        });
        let cfg = MeasureConfig {
            k: c.k,
            ..Default::default()
        };
        for kind in [
            MeasureKind::SimplifiedKnn,
            MeasureKind::Knn,
            MeasureKind::Kde,
            MeasureKind::LsSvm,
        ] {
            let mut live = build_measure(kind, &cfg, None);
            live.fit(&train);
            let mut reduced = train.clone();
            for idx in [reduced.n() - 1, 0] {
                if !live.unlearn(idx) {
                    return false;
                }
                reduced.remove(idx);
                let mut fresh = build_measure(kind, &cfg, None);
                fresh.fit(&reduced);
                for i in 0..probe.n() {
                    for y in 0..train.n_labels {
                        let a = p_value(&live.scores(probe.row(i), y));
                        let b = p_value(&fresh.scores(probe.row(i), y));
                        if (a - b).abs() > 1e-8 {
                            return false;
                        }
                    }
                }
            }
            if live.unlearn(reduced.n()) {
                return false; // out of range must be rejected
            }
        }
        true
    });
}
