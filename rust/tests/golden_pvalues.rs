//! Golden p-value regression fixtures.
//!
//! A fixed, hard-coded dataset is scored through `FullCp::p_values_batch`
//! for each deterministic measure (standard AND optimized variants) and
//! compared against checked-in expected p-values, so future refactors of
//! the scoring engine cannot silently shift p-values.
//!
//! The expected values were computed by an independent reference
//! implementation of the *standard* measure definitions (straight from
//! the paper's formulas — Eq. 2 k-NN, §4 KDE, §5 LS-SVM ridge closed
//! form). p-values are counts over score comparisons whose minimum
//! relative margin on this dataset is ~3e-5, so they are robust to any
//! plausible float-level difference (libm ulps, summation order,
//! rank-1-update vs refactorization noise, all <= ~1e-9 relative).
//!
//! Random Forest is covered by determinism/shape assertions instead of
//! an external golden: its scores depend on the in-tree xoshiro RNG
//! stream driving bootstrap draws and tree fitting, which no external
//! reference can reproduce; its batch-vs-single exactness is enforced
//! bit-for-bit by `proptests.rs`.

use exact_cp::config::{MeasureConfig, MeasureKind};
use exact_cp::coordinator::factory::{build_measure, build_standard_measure};
use exact_cp::cp::FullCp;
use exact_cp::data::Dataset;
use exact_cp::measures::{BootstrapOptimized, BootstrapParams};

/// 24 x 3 training matrix (two Gaussian clusters, labels alternating).
#[rustfmt::skip]
const X: [f64; 72] = [
    1.8689, -1.8382, -1.8353, 3.0792, 3.2826, 1.4304,
    -0.8888, -1.3879, -1.727, 2.7131, 2.71, 0.1144,
    -1.329, 1.0978, 0.7667, 2.7225, 2.3393, 3.1279,
    0.1184, 1.2551, -0.0323, 2.033, 2.353, 3.0523,
    0.6885, 0.477, 0.9824, 3.6626, 2.6977, 3.6707,
    0.9283, 0.9368, -0.4664, 2.781, 2.4908, 2.7889,
    -0.9325, -1.0851, 2.6148, 2.0149, 1.6608, 3.6226,
    -1.1739, 0.4471, 1.2732, 3.6216, 2.5469, 1.5857,
    -0.2189, -0.6261, 1.1392, 2.8734, 1.0989, 2.5236,
    1.5275, -1.1739, -0.0394, 2.9779, 2.1853, 3.7047,
    0.6465, 1.5011, -0.9071, 0.8411, 1.6495, 2.0831,
    0.0166, 0.2737, -1.7988, 2.9863, 1.0917, 3.1274,
];

/// Probes: near cluster 0, near cluster 1, boundary, far outlier.
#[rustfmt::skip]
const PROBES: [[f64; 3]; 4] = [
    [0.2178, -0.5564, 0.9613],
    [2.086, 3.5415, 3.6043],
    [1.3028, 1.056, 1.9506],
    [4.9996, -4.2977, 6.3195],
];

fn train_ds() -> Dataset {
    let y: Vec<usize> = (0..24).map(|i| i % 2).collect();
    Dataset::new(X.to_vec(), y, 3, 2)
}

/// Golden per-probe [p(y=0), p(y=1)] rows (all multiples of 1/25).
fn golden(kind: MeasureKind) -> [[f64; 2]; 4] {
    match kind {
        MeasureKind::SimplifiedKnn => {
            [[0.68, 0.04], [0.04, 0.44], [0.20, 0.44], [0.04, 0.04]]
        }
        MeasureKind::Knn => {
            [[0.68, 0.04], [0.04, 0.72], [0.04, 0.12], [0.04, 0.08]]
        }
        MeasureKind::Kde => {
            [[0.64, 0.04], [0.04, 0.52], [0.20, 0.48], [0.04, 0.04]]
        }
        MeasureKind::LsSvm => {
            [[0.40, 0.44], [0.04, 0.96], [0.04, 0.52], [0.04, 0.60]]
        }
        MeasureKind::RandomForest => unreachable!("no external golden"),
    }
}

fn assert_rows_match(kind: MeasureKind, variant: &str, rows: &[Vec<f64>]) {
    let want = golden(kind);
    assert_eq!(rows.len(), want.len());
    for (i, (row, want_row)) in rows.iter().zip(&want).enumerate() {
        assert_eq!(row.len(), 2);
        for (y, (&got, &want)) in row.iter().zip(want_row).enumerate() {
            assert!(
                (got - want).abs() < 1e-9,
                "{kind:?} ({variant}) probe={i} y={y}: got {got}, want {want}"
            );
        }
    }
}

#[test]
fn golden_pvalues_deterministic_measures() {
    let ds = train_ds();
    let cfg = MeasureConfig {
        k: 3,
        h: 1.0,
        rho: 1.0,
        ..Default::default()
    };
    let xs: Vec<&[f64]> = PROBES.iter().map(|p| p.as_slice()).collect();
    for kind in [
        MeasureKind::SimplifiedKnn,
        MeasureKind::Knn,
        MeasureKind::Kde,
        MeasureKind::LsSvm,
    ] {
        let opt = FullCp::train(build_measure(kind, &cfg, None), &ds);
        assert_rows_match(kind, "optimized", &opt.p_values_batch(&xs));
        let std_cp = FullCp::train(build_standard_measure(kind, &cfg), &ds);
        assert_rows_match(kind, "standard", &std_cp.p_values_batch(&xs));
        // the batch path must agree with the single-object path too
        for (x, row) in xs.iter().zip(opt.p_values_batch(&xs)) {
            assert_eq!(row, opt.p_values(x), "{kind:?} batch vs single");
        }
    }
}

#[test]
fn golden_random_forest_is_deterministic_and_valid() {
    // No external golden (in-tree RNG drives bootstrap + tree fits);
    // instead: two fresh instances agree exactly, the batch path equals
    // the single path (also enforced by proptests), and p-values are
    // valid multiples of 1/(n+1).
    let ds = train_ds();
    let params = BootstrapParams {
        b: 5,
        ..Default::default()
    };
    let xs: Vec<&[f64]> = PROBES.iter().map(|p| p.as_slice()).collect();
    let a = FullCp::train(BootstrapOptimized::new(params.clone()), &ds);
    let b = FullCp::train(BootstrapOptimized::new(params), &ds);
    let rows_a = a.p_values_batch(&xs);
    let rows_b = b.p_values_batch(&xs);
    assert_eq!(rows_a, rows_b, "fresh instances must agree exactly");
    for (x, row) in xs.iter().zip(&rows_a) {
        assert_eq!(row, &a.p_values(x), "batch vs single");
        for &p in row {
            assert!((1.0 / 25.0..=1.0).contains(&p), "p out of range: {p}");
            let scaled = p * 25.0;
            assert!(
                (scaled - scaled.round()).abs() < 1e-9,
                "p not a multiple of 1/25: {p}"
            );
        }
    }
}
