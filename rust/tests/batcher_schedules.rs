//! Deterministic-interleaving tests for the dynamic batcher.
//!
//! Every batcher operation (`push`, `next_batch`, `close`, `depth`) is
//! a single critical section under one mutex, so any concurrent run is
//! observationally equivalent to *some* serialization of those critical
//! sections. That makes the batcher model-checkable without a custom
//! scheduler: enumerate every interleaving of the per-actor operation
//! sequences (a DFS over enabled transitions), replay each schedule
//! against a fresh real `Batcher`, and compare every observation with a
//! trivial FIFO reference model.
//!
//! `max_wait = Duration::ZERO` removes the straggler timer from the
//! picture (the timed wait becomes a no-op), and `Drain` is only
//! *enabled* when the queue is non-empty or closed, so an enabled drain
//! never blocks. Scenarios always carry a `Close`, so the DFS can never
//! strand a consumer: while `Close` is pending some producer actor is
//! runnable, and afterwards drains are always enabled.

use std::collections::VecDeque;
use std::time::Duration;

use exact_cp::coordinator::batcher::{Batcher, PushError};

/// One batcher operation, attributed to an actor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Push(i32),
    Close,
    Drain,
}

/// What a schedule step observed (identical for model and real runs).
#[derive(Debug, Clone, PartialEq, Eq)]
enum Obs {
    Pushed(Result<(), ModelPushError>),
    Closed,
    Drained(Option<Vec<i32>>),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ModelPushError {
    Full,
    Closed,
}

/// The reference model: a plain FIFO with a cap and a closed flag.
struct Model {
    items: VecDeque<i32>,
    closed: bool,
    max_batch: usize,
    capacity: usize,
}

impl Model {
    fn new(max_batch: usize, capacity: usize) -> Model {
        Model {
            items: VecDeque::new(),
            closed: false,
            max_batch,
            capacity,
        }
    }

    fn enabled(&self, op: Op) -> bool {
        match op {
            Op::Push(_) | Op::Close => true,
            Op::Drain => !self.items.is_empty() || self.closed,
        }
    }

    fn step(&mut self, op: Op) -> Obs {
        match op {
            Op::Push(v) => Obs::Pushed(if self.closed {
                Err(ModelPushError::Closed)
            } else if self.items.len() >= self.capacity {
                Err(ModelPushError::Full)
            } else {
                self.items.push_back(v);
                Ok(())
            }),
            Op::Close => {
                self.closed = true;
                Obs::Closed
            }
            Op::Drain => {
                if self.items.is_empty() {
                    Obs::Drained(None)
                } else {
                    let take = self.items.len().min(self.max_batch);
                    Obs::Drained(Some(self.items.drain(..take).collect()))
                }
            }
        }
    }
}

/// DFS over all interleavings of the actor programs, collecting each
/// complete schedule as a flat op sequence.
fn schedules(actors: &[Vec<Op>]) -> Vec<Vec<Op>> {
    fn rec(
        actors: &[Vec<Op>],
        pc: &mut Vec<usize>,
        model: &mut Model,
        trace: &mut Vec<Op>,
        out: &mut Vec<Vec<Op>>,
    ) {
        let mut advanced = false;
        for (a, prog) in actors.iter().enumerate() {
            if pc[a] >= prog.len() {
                continue;
            }
            let op = prog[pc[a]];
            if !model.enabled(op) {
                continue;
            }
            advanced = true;
            // snapshot-free undo: re-run the prefix on a fresh model
            pc[a] += 1;
            trace.push(op);
            let mut m2 = Model::new(model.max_batch, model.capacity);
            for &o in trace.iter() {
                m2.step(o);
            }
            rec(actors, pc, &mut m2, trace, out);
            trace.pop();
            pc[a] -= 1;
        }
        if !advanced {
            let done = pc
                .iter()
                .zip(actors)
                .all(|(&c, prog)| c >= prog.len());
            assert!(done, "stuck schedule (lost wakeup in the model?): {trace:?}");
            out.push(trace.clone());
        }
    }
    let mut out = Vec::new();
    let mut pc = vec![0; actors.len()];
    let mut model = Model::new(
        MAX_BATCH,
        CAPACITY, // schedules() is only used with these params
    );
    let mut trace = Vec::new();
    rec(actors, &mut pc, &mut model, &mut trace, &mut out);
    out
}

const MAX_BATCH: usize = 2;
const CAPACITY: usize = 3;

/// Run one schedule against the model and against a real batcher
/// (`max_wait = ZERO`, so enabled drains return immediately), asserting
/// identical observations at every step and identical final depth.
fn replay(schedule: &[Op]) {
    let mut model = Model::new(MAX_BATCH, CAPACITY);
    let real = Batcher::new(MAX_BATCH, Duration::ZERO, CAPACITY);
    for (i, &op) in schedule.iter().enumerate() {
        let want = model.step(op);
        let got = match op {
            Op::Push(v) => Obs::Pushed(match real.push(v) {
                Ok(()) => Ok(()),
                Err(PushError::Full) => Err(ModelPushError::Full),
                Err(PushError::Closed) => Err(ModelPushError::Closed),
            }),
            Op::Close => {
                real.close();
                Obs::Closed
            }
            Op::Drain => Obs::Drained(real.next_batch()),
        };
        assert_eq!(got, want, "step {i} of {schedule:?}");
    }
    assert_eq!(real.depth(), model.items.len(), "final depth {schedule:?}");
}

#[test]
fn two_producers_one_consumer_all_interleavings() {
    let actors = vec![
        vec![Op::Push(1), Op::Push(2), Op::Close],
        vec![Op::Push(10)],
        vec![Op::Drain, Op::Drain, Op::Drain],
    ];
    let all = schedules(&actors);
    assert!(
        all.len() >= 10,
        "expected a nontrivial schedule space, got {}",
        all.len()
    );
    for s in &all {
        replay(s);
    }
}

#[test]
fn overflow_and_post_close_drains_all_interleavings() {
    // capacity 3: the fourth concurrent push must observe Full in the
    // interleavings where it lands before any drain
    let actors = vec![
        vec![Op::Push(1), Op::Push(2)],
        vec![Op::Push(3), Op::Push(4), Op::Close],
        vec![Op::Drain, Op::Drain, Op::Drain, Op::Drain],
    ];
    let all = schedules(&actors);
    assert!(all.len() >= 10, "got {}", all.len());
    let mut saw_full = false;
    let mut saw_closed_push = false;
    for s in &all {
        replay(s);
        // classify via the model to assert the space covers both edges
        let mut m = Model::new(MAX_BATCH, CAPACITY);
        for &op in s {
            match m.step(op) {
                Obs::Pushed(Err(ModelPushError::Full)) => saw_full = true,
                Obs::Pushed(Err(ModelPushError::Closed)) => {
                    saw_closed_push = true
                }
                _ => {}
            }
        }
    }
    assert!(saw_full, "no interleaving exercised backpressure");
    assert!(saw_closed_push, "no interleaving pushed after close");
}

#[test]
fn drains_after_close_never_yield_items_pushed_after_close() {
    let actors = vec![
        vec![Op::Push(1), Op::Close, Op::Push(99)],
        vec![Op::Drain, Op::Drain],
    ];
    for s in &schedules(&actors) {
        replay(s);
        // additionally: 99 must never be observable anywhere
        let mut m = Model::new(MAX_BATCH, CAPACITY);
        for &op in s {
            if let Obs::Drained(Some(batch)) = m.step(op) {
                assert!(
                    !batch.contains(&99),
                    "drained an item pushed after close: {s:?}"
                );
            }
        }
    }
}

/// Real-thread stress against lost wakeups: producers and consumers run
/// concurrently; when the batcher closes, every consumer must wake and
/// exit, and the union of drained batches must be exactly the accepted
/// pushes, each exactly once.
#[test]
fn threaded_no_lost_wakeups_no_lost_items() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    const PRODUCERS: usize = 3;
    const CONSUMERS: usize = 2;
    const PER_PRODUCER: i32 = 200;

    for round in 0..20 {
        let b: Arc<Batcher<i32>> =
            Arc::new(Batcher::new(7, Duration::from_micros(50), 64));
        let accepted = Arc::new(Mutex::new(Vec::new()));
        let drained = Arc::new(Mutex::new(Vec::new()));
        let live = Arc::new(AtomicUsize::new(CONSUMERS));

        std::thread::scope(|scope| {
            for c in 0..CONSUMERS {
                let b = b.clone();
                let drained = drained.clone();
                let live = live.clone();
                scope.spawn(move || {
                    while let Some(batch) = b.next_batch() {
                        drained.lock().unwrap().extend(batch);
                        if c == 0 {
                            std::thread::yield_now();
                        }
                    }
                    live.fetch_sub(1, Ordering::SeqCst);
                });
            }
            let producers: Vec<_> = (0..PRODUCERS)
                .map(|pi| {
                    let b = b.clone();
                    let accepted = accepted.clone();
                    scope.spawn(move || {
                        for k in 0..PER_PRODUCER {
                            let v = pi as i32 * 10_000 + k + round;
                            loop {
                                match b.push(v) {
                                    Ok(()) => {
                                        accepted.lock().unwrap().push(v);
                                        break;
                                    }
                                    Err(PushError::Full) => {
                                        std::thread::yield_now()
                                    }
                                    Err(PushError::Closed) => {
                                        panic!("closed during production")
                                    }
                                }
                            }
                        }
                    })
                })
                .collect();
            for p in producers {
                p.join().unwrap();
            }
            b.close();
        });

        assert_eq!(
            live.load(Ordering::SeqCst),
            0,
            "round {round}: a consumer missed the close wakeup"
        );
        let mut acc = accepted.lock().unwrap().clone();
        let mut got = drained.lock().unwrap().clone();
        acc.sort_unstable();
        got.sort_unstable();
        assert_eq!(
            got, acc,
            "round {round}: drained multiset != accepted multiset"
        );
    }
}
