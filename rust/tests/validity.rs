//! Statistical validity tests: the CP guarantee
//! Pr(y not in Gamma^eps) <= eps under exchangeability (paper §2), for
//! every measure family, plus p-value uniformity and the classification
//! quality expected on separable data.

use exact_cp::config::{MeasureConfig, MeasureKind};
use exact_cp::coordinator::factory::build_measure;
use exact_cp::cp::icp::Icp;
use exact_cp::cp::metrics::{avg_set_size, coverage};
use exact_cp::cp::pvalue::p_value;
use exact_cp::data::{make_classification, ClassificationSpec, Rng};
use exact_cp::measures::IcpKnn;
use exact_cp::regression::KnnRegressorOptimized;

fn p_matrix(
    kind: MeasureKind,
    cfg: &MeasureConfig,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> (Vec<Vec<f64>>, Vec<usize>) {
    let all = make_classification(
        &ClassificationSpec {
            n_samples: n_train + n_test,
            n_features: 10,
            n_informative: 4,
            n_redundant: 2,
            ..Default::default()
        },
        seed,
    );
    let mut rng = Rng::seed_from(seed + 1);
    let (train, test) = all.split(n_train, &mut rng);
    let mut m = build_measure(kind, cfg, None);
    m.fit(&train);
    let pm: Vec<Vec<f64>> = (0..test.n())
        .map(|i| {
            (0..train.n_labels)
                .map(|y| p_value(&m.scores(test.row(i), y)))
                .collect()
        })
        .collect();
    (pm, test.y.clone())
}

/// Empirical coverage must be >= 1 - eps - fuzz for each measure.
#[test]
fn coverage_guarantee_all_measures() {
    let cfg = MeasureConfig {
        k: 5,
        b: 10,
        ..Default::default()
    };
    for kind in [
        MeasureKind::SimplifiedKnn,
        MeasureKind::Knn,
        MeasureKind::Kde,
        MeasureKind::LsSvm,
        MeasureKind::RandomForest,
    ] {
        let (pm, truth) = p_matrix(kind, &cfg, 150, 100, 7);
        for eps in [0.05, 0.1, 0.2] {
            let cov = coverage(&pm, &truth, eps);
            // binomial fuzz at n_test=100: 3 sigma ~ 0.12 at eps=0.2
            assert!(
                cov >= 1.0 - eps - 0.13,
                "{kind:?} eps={eps}: coverage {cov}"
            );
        }
    }
}

/// Prediction sets must be informative (avg size well below |Y|) on
/// separable data for the NN-family measures.
#[test]
fn sets_are_informative() {
    let cfg = MeasureConfig {
        k: 5,
        ..Default::default()
    };
    let (pm, _) = p_matrix(MeasureKind::SimplifiedKnn, &cfg, 200, 80, 9);
    let size = avg_set_size(&pm, 0.2);
    assert!(size < 1.7, "avg set size {size} at eps=0.2");
}

/// True-label p-values are ~uniform under exchangeability: the CDF at q
/// should be ~q.
#[test]
fn true_label_pvalues_uniform() {
    let cfg = MeasureConfig {
        k: 5,
        ..Default::default()
    };
    let (pm, truth) = p_matrix(MeasureKind::Knn, &cfg, 150, 150, 11);
    let ps: Vec<f64> = pm.iter().zip(&truth).map(|(row, &y)| row[y]).collect();
    for q in [0.1, 0.25, 0.5, 0.75] {
        let frac = ps.iter().filter(|&&p| p <= q).count() as f64 / ps.len() as f64;
        assert!(
            (frac - q).abs() < 0.12,
            "P(p <= {q}) = {frac}, expected ~{q}"
        );
    }
}

/// ICP also has valid coverage (Algorithm 2).
#[test]
fn icp_coverage_guarantee() {
    let all = make_classification(
        &ClassificationSpec {
            n_samples: 300,
            ..Default::default()
        },
        13,
    );
    let mut rng = Rng::seed_from(14);
    let (train, test) = all.split(200, &mut rng);
    let icp = Icp::calibrate(IcpKnn::new(5, true), &train, 100);
    let pm: Vec<Vec<f64>> = (0..test.n()).map(|i| icp.p_values(test.row(i))).collect();
    for eps in [0.1, 0.2] {
        let cov = coverage(&pm, &test.y, eps);
        assert!(cov >= 1.0 - eps - 0.13, "eps={eps}: {cov}");
    }
}

/// Full CP is at least as statistically efficient as ICP here: smaller
/// or comparable prediction sets at matched eps (the paper's App. G
/// finding, on the synthetic workload).
#[test]
fn full_cp_no_less_efficient_than_icp() {
    let all = make_classification(
        &ClassificationSpec {
            n_samples: 260,
            ..Default::default()
        },
        15,
    );
    let mut rng = Rng::seed_from(16);
    let (train, test) = all.split(200, &mut rng);
    let cfg = MeasureConfig {
        k: 5,
        ..Default::default()
    };
    let mut m = build_measure(MeasureKind::SimplifiedKnn, &cfg, None);
    m.fit(&train);
    let pm_cp: Vec<Vec<f64>> = (0..test.n())
        .map(|i| {
            (0..2)
                .map(|y| p_value(&m.scores(test.row(i), y)))
                .collect()
        })
        .collect();
    let icp = Icp::calibrate(IcpKnn::new(5, true), &train, 100);
    let pm_icp: Vec<Vec<f64>> =
        (0..test.n()).map(|i| icp.p_values(test.row(i))).collect();
    let s_cp = avg_set_size(&pm_cp, 0.15);
    let s_icp = avg_set_size(&pm_icp, 0.15);
    assert!(
        s_cp <= s_icp + 0.15,
        "full CP sets ({s_cp}) should not be larger than ICP's ({s_icp})"
    );
}

/// Regression coverage: the 1-eps region contains the true target at
/// the guaranteed rate.
#[test]
fn regression_coverage_guarantee() {
    use exact_cp::data::{make_regression, RegressionSpec};
    let all = make_regression(
        &RegressionSpec {
            n_samples: 260,
            n_features: 8,
            n_informative: 4,
            noise: 10.0,
        },
        17,
    );
    let mut rng = Rng::seed_from(18);
    let (train, test) = all.split(200, &mut rng);
    let mut m = KnnRegressorOptimized::new(5);
    m.fit(&train);
    for eps in [0.1, 0.3] {
        let covered = (0..test.n())
            .filter(|&i| m.predict_region(test.row(i), eps).contains(test.y[i]))
            .count();
        let rate = covered as f64 / test.n() as f64;
        assert!(rate >= 1.0 - eps - 0.14, "eps={eps}: coverage {rate}");
    }
}
