#!/usr/bin/env python3
"""Reference implementation generating golden p-values for
golden_pvalues.rs (same directory).

Replicates the *standard* variants of the Rust measures (knn.rs,
kde.rs, lssvm.rs) on a fixed, hard-coded dataset. p-values are counts
(#{alpha_i >= alpha_test}+1)/(n+1), so they are robust to <=1e-9 score
noise as long as every comparison margin is large; this script asserts
the margins.
"""
import math
import random

N, P = 24, 3
K = 3
H = 1.0
RHO = 1.0
NPROBE = 4

rng = random.Random(20260728)

def gen_point(center, spread=1.0):
    return [round(center[j] + rng.gauss(0, spread), 4) for j in range(P)]

C0 = (0.0, 0.0, 0.0)
C1 = (2.5, 2.5, 2.5)

X, Y = [], []
for i in range(N):
    c = i % 2
    X.append(gen_point(C0 if c == 0 else C1))
    Y.append(c)

PROBES = [
    gen_point(C0),            # clearly class 0
    gen_point(C1),            # clearly class 1
    gen_point((1.25, 1.25, 1.25)),  # boundary
    gen_point((6.0, -4.0, 6.0)),    # outlier
]

def dist(a, b):
    s = 0.0
    for u, v in zip(a, b):
        d = u - v
        s += d * d
    return math.sqrt(s)

def ksum(vals, k):
    vals = sorted(vals)[:k]
    if not vals:
        return (0, float("inf"))
    return (len(vals), math.fsum(vals))

def knn_ratio(nl, num, dl, den):
    if nl == 0 and dl == 0:
        return 1.0
    if nl == 0:
        return float("inf")
    if dl == 0:
        return 0.0
    if den == 0.0:
        return 1.0 if num == 0.0 else float("inf")
    return num / den

def knn_scores(x, y, simplified):
    """standard (simplified-)knn: returns (train list, test)."""
    train = []
    for i in range(N):
        same, diff = [], []
        for j in range(N):
            if j == i:
                continue
            d = dist(X[i], X[j])
            (same if Y[j] == Y[i] else diff).append(d)
        dtest = dist(X[i], x)
        (same if y == Y[i] else diff).append(dtest)
        nl, num = ksum(same, K)
        if simplified:
            train.append(num if nl else float("inf"))
        else:
            dl, den = ksum(diff, K)
            train.append(knn_ratio(nl, num, dl, den))
    same = [dist(x, X[j]) for j in range(N) if Y[j] == y]
    diff = [dist(x, X[j]) for j in range(N) if Y[j] != y]
    nl, num = ksum(same, K)
    if simplified:
        test = num if nl else float("inf")
    else:
        dl, den = ksum(diff, K)
        test = knn_ratio(nl, num, dl, den)
    return train, test

def kern(a, b):
    d2 = 0.0
    for u, v in zip(a, b):
        d = u - v
        d2 += d * d
    return math.exp(-d2 / (2.0 * H * H))

def kde_scores(x, y):
    counts = [Y.count(c) for c in range(2)]
    train = []
    for i in range(N):
        s = math.fsum(kern(X[i], X[j]) for j in range(N)
                      if j != i and Y[j] == Y[i])
        ny = counts[Y[i]] - 1
        if y == Y[i]:
            s += kern(X[i], x)
            ny += 1
        train.append(-(s / ny) if ny else 0.0)
    s = math.fsum(kern(x, X[j]) for j in range(N) if Y[j] == y)
    test = -(s / counts[y]) if counts[y] else 0.0
    return train, test

def solve3(A, b):
    """Gaussian elimination with partial pivoting, 3x3."""
    A = [row[:] for row in A]
    b = b[:]
    n = len(b)
    for c in range(n):
        piv = max(range(c, n), key=lambda r: abs(A[r][c]))
        A[c], A[piv] = A[piv], A[c]
        b[c], b[piv] = b[piv], b[c]
        for r in range(c + 1, n):
            f = A[r][c] / A[c][c]
            for cc in range(c, n):
                A[r][cc] -= f * A[c][cc]
            b[r] -= f * b[c]
    x = [0.0] * n
    for r in range(n - 1, -1, -1):
        s = b[r] - sum(A[r][cc] * x[cc] for cc in range(r + 1, n))
        x[r] = s / A[r][r]
    return x

def ridge_w(rows, ts):
    A = [[sum(r[i] * r[j] for r in rows) + (RHO if i == j else 0.0)
          for j in range(P)] for i in range(P)]
    b = [sum(t * r[i] for r, t in zip(rows, ts)) for i in range(P)]
    return solve3(A, b)

def lssvm_scores(x, y):
    t = -1.0 if y == 0 else 1.0
    ts = [-1.0 if c == 0 else 1.0 for c in Y]
    aug = X + [x]
    taug = ts + [t]
    train = []
    for i in range(N):
        rows = [aug[j] for j in range(N + 1) if j != i]
        tt = [taug[j] for j in range(N + 1) if j != i]
        w = ridge_w(rows, tt)
        f = sum(wi * xi for wi, xi in zip(w, X[i]))
        train.append(-ts[i] * f)
    w = ridge_w(X, ts)
    f = sum(wi * xi for wi, xi in zip(w, x))
    test = -t * f
    return train, test

def p_value(train, test):
    ge = sum(1 for a in train if a >= test)
    return (ge + 1) / (N + 1)

def margin(train, test):
    finite = [abs(a - test) / (1.0 + abs(test))
              for a in train if math.isfinite(a) and math.isfinite(test)]
    return min(finite) if finite else float("inf")

MEASURES = {
    "simplified-knn": lambda x, y: knn_scores(x, y, True),
    "knn": lambda x, y: knn_scores(x, y, False),
    "kde": kde_scores,
    "lssvm": lssvm_scores,
}

golden = {}
min_margin = float("inf")
for name, fn in MEASURES.items():
    rows = []
    for x in PROBES:
        row = []
        for y in range(2):
            tr, te = fn(x, y)
            m = margin(tr, te)
            min_margin = min(min_margin, m)
            if m < 1e-6:
                print(f"WARNING: tight margin {m:.2e} for {name} x={x} y={y}")
            row.append(p_value(tr, te))
        rows.append(row)
    golden[name] = rows

print(f"min relative margin: {min_margin:.3e}")
print()

def fmt_row(vals, per=6):
    return ", ".join(f"{v}" for v in vals)

print("// ---- training set (24 x 3, labels alternate 0/1) ----")
flat = [v for row in X for v in row]
print("X flat:")
for i in range(0, len(flat), 6):
    print("    " + ", ".join(f"{v}" for v in flat[i:i+6]) + ",")
print("Y:", Y)
print("PROBES:")
for p in PROBES:
    print("    " + ", ".join(f"{v}" for v in p) + ",")
print()
for name, rows in golden.items():
    print(f"{name}:")
    for r in rows:
        print("    ", r)
