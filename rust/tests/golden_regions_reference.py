#!/usr/bin/env python3
"""Independent reference for tests/golden_regions.rs.

Regenerate the fixture block with:

    python3 tests/golden_regions_reference.py > /tmp/golden.rs

and paste the output into golden_regions.rs between the GENERATED
markers.

The point of this script is INDEPENDENCE from the Rust implementation:

* k-NN coefficients are computed with explicit sorted neighbour lists
  (no select_nth, no precomputed statistics), following the paper's
  formulas directly: with (distance, index) neighbour ordering and the
  strict d(x_i, x) < Delta_i^k entry rule,

      x in kNN(x_i):  a_i = y_i - (1/k) sum_{k-1} ,  b_i = -1/k
      otherwise:      a_i = y_i - (1/k) sum_k     ,  b_i = 0
      test:           a   = -(1/k) sum_k(x)       ,  b   = 1

* ridge (RRCM) coefficients come from the explicit augmented hat matrix
  H = Xa (Xa^T Xa + rho I)^-1 Xa^T over the (n+1)-row design — no
  Sherman-Morrison shortcut.

* regions are assembled from scratch: collect the critical points
  (roots of (a_i -+ a) + (b_i -+ b) y = 0), then classify every open
  segment between consecutive roots by evaluating the direct p-value at
  its midpoint. The region is the closure of the in-region segments
  (conformal regions from |.| score ties are closed sets).

The generator asserts safety margins so that float noise between the
two implementations cannot flip any discrete decision:
  * consecutive critical points separated by > 1e-5,
  * k-NN entry decisions and neighbour selections decided by > 1e-7,
  * score ties at the golden candidate labels bounded away by > 1e-7,
  * all regions bounded (no infinite endpoints),
  * no isolated single-point region components.
"""

import math
import random

import numpy as np

N, P, K, RHO = 24, 3, 3, 1.0
EPSES = (0.1, 0.3)

rng = random.Random(20210707)
X = [[round(rng.uniform(-3.0, 3.0), 4) for _ in range(P)] for _ in range(N)]


def signal(row):
    return 2.0 * row[0] - 1.5 * row[1] + 0.5 * row[2]


Y = [round(signal(r) + rng.gauss(0.0, 1.0), 4) for r in X]
PROBES = [[round(rng.uniform(-3.0, 3.0), 4) for _ in range(P)] for _ in range(4)]
CAND_Y = [round(signal(p) + rng.gauss(0.0, 1.0), 4) for p in PROBES]


def dist(u, v):
    return math.sqrt(sum((a - b) ** 2 for a, b in zip(u, v)))


def knn_coefs(xs, ys, x):
    """Explicit k-NN CP coefficients for test object x on (xs, ys)."""
    n = len(ys)
    coefs = []
    d_test = [dist(xs[i], x) for i in range(n)]
    for i in range(n):
        items = sorted(
            ((dist(xs[i], xs[j]), j) for j in range(n) if j != i)
        )
        # neighbour selection must be decided by a clear margin
        assert items[K][0] - items[K - 1][0] > 1e-7, "kNN tie at boundary"
        sum_k = sum(ys[j] for _, j in items[:K])
        sum_k1 = sum(ys[j] for _, j in items[: K - 1])
        delta_k = items[K - 1][0]
        assert abs(d_test[i] - delta_k) > 1e-7, "entry decision too close"
        if d_test[i] < delta_k:
            coefs.append((ys[i] - sum_k1 / K, -1.0 / K))
        else:
            coefs.append((ys[i] - sum_k / K, 0.0))
    items = sorted((d_test[j], j) for j in range(n))
    assert items[K][0] - items[K - 1][0] > 1e-7, "test kNN tie at boundary"
    a = -sum(ys[j] for _, j in items[:K]) / K
    return coefs, a, 1.0


def ridge_coefs(xs, ys, x):
    """Explicit augmented-hat-matrix RRCM coefficients on (xs, ys)."""
    n = len(ys)
    xa = np.vstack([np.array(xs, dtype=float), np.array(x, dtype=float)])
    minv = np.linalg.inv(xa.T @ xa + RHO * np.eye(P))
    y0 = np.append(np.array(ys, dtype=float), 0.0)
    e = np.zeros(n + 1)
    e[n] = 1.0
    w_a = minv @ (xa.T @ y0)
    w_b = minv @ (xa.T @ e)
    coefs = [
        (y0[i] - float(xa[i] @ w_a), e[i] - float(xa[i] @ w_b))
        for i in range(n)
    ]
    a = y0[n] - float(xa[n] @ w_a)
    b = e[n] - float(xa[n] @ w_b)
    return coefs, a, b


def p_value(coefs, a, b, y):
    alpha = abs(a + b * y)
    ge = sum(1 for ai, bi in coefs if abs(ai + bi * y) >= alpha)
    return (ge + 1) / (len(coefs) + 1)


def region(coefs, a, b, eps):
    """Closure of {y : p(y) > eps}, assembled by segment classification."""
    pts = set()
    for ai, bi in coefs:
        for c, s in ((ai - a, bi - b), (ai + a, bi + b)):
            if abs(s) > 1e-12:
                pts.add(float(-c / s))
    roots = sorted(pts)
    for r1, r2 in zip(roots, roots[1:]):
        assert r2 - r1 > 1e-5, f"critical points too close: {r1} {r2}"
    mids = [roots[0] - 1.0]
    mids += [(r1 + r2) / 2.0 for r1, r2 in zip(roots, roots[1:])]
    mids.append(roots[-1] + 1.0)
    seg_in = [p_value(coefs, a, b, m) > eps for m in mids]
    assert not seg_in[0] and not seg_in[-1], "region must be bounded"
    # closed-set semantics: a root with p > eps must touch an in-region
    # segment (no isolated points — would complicate the fixture)
    for idx, r in enumerate(roots):
        if p_value(coefs, a, b, r) > eps:
            assert seg_in[idx] or seg_in[idx + 1], f"isolated point at {r}"
    out, start = [], None
    for i, s in enumerate(seg_in):
        if s and start is None:
            start = roots[i - 1]
        if not s and start is not None:
            out.append((start, roots[i - 1]))
            start = None
    assert start is None
    return out


def tie_margin(coefs, a, b, y):
    alpha = abs(a + b * y)
    return min(abs(abs(ai + bi * y) - alpha) for ai, bi in coefs)


def flat(rows):
    return [v for row in rows for v in row]


def fmt(vals, per_line=6):
    lines = []
    for i in range(0, len(vals), per_line):
        lines.append(", ".join(repr(float(v)) for v in vals[i : i + per_line]))
    return ",\n    ".join(lines)


print("// ---- GENERATED by golden_regions_reference.py — do not edit ----")
print(f"const X: [f64; {N * P}] = [\n    {fmt(flat(X))},\n];")
print(f"const Y: [f64; {N}] = [\n    {fmt(Y)},\n];")
print(f"const PROBES: [f64; {4 * P}] = [\n    {fmt(flat(PROBES))},\n];")
print(f"const CAND_Y: [f64; 4] = [\n    {fmt(CAND_Y)},\n];")

for name, fn in (("KNN", knn_coefs), ("RIDGE", ridge_coefs)):
    golden, pvals = [], []
    for probe, cy in zip(PROBES, CAND_Y):
        coefs, a, b = fn(X, Y, probe)
        per_eps = []
        for eps in EPSES:
            per_eps.append(region(coefs, a, b, eps))
        golden.append(per_eps)
        assert tie_margin(coefs, a, b, cy) > 1e-7, "p-value tie too close"
        pvals.append(p_value(coefs, a, b, cy))
    print(f"/// Golden intervals per probe: (eps = {EPSES[0]}, eps = {EPSES[1]}).")
    print(
        f"const {name}_REGIONS: [(&[(f64, f64)], &[(f64, f64)]); 4] = ["
    )
    for per_eps in golden:
        cells = []
        for ivs in per_eps:
            body = ", ".join(f"({repr(lo)}, {repr(hi)})" for lo, hi in ivs)
            cells.append(f"&[{body}]")
        print(f"    ({cells[0]}, {cells[1]}),")
    print("];")
    print(
        f"const {name}_PVALS: [f64; 4] = [{', '.join(repr(p) for p in pvals)}];"
    )

# ---------------------------------------------------------------------
# Scripted learn/unlearn sequence (decremental serving golden).
#
# The Rust test replays the SAME op script against the online
# learn/unlearn paths of each regressor; the reference recomputes every
# step from scratch on the mutated dataset — so any drift the journal
# or neighbour-statistics maintenance accumulates across a realistic
# grow/shrink sequence shows up as a per-step diff, not just at the end.
# ---------------------------------------------------------------------
SEQ_LEARN_X = [0.5, -1.2, 0.8]
SEQ_LEARN_Y = 2.05
# (op, index): unlearn of last / first / middle rows around one learn
SEQ = [("unlearn", 23), ("unlearn", 0), ("learn", None), ("unlearn", 11)]


def seq_states():
    xs = [list(r) for r in X]
    ys = list(Y)
    for op, idx in SEQ:
        if op == "unlearn":
            xs.pop(idx)
            ys.pop(idx)
        else:
            xs.append(list(SEQ_LEARN_X))
            ys.append(SEQ_LEARN_Y)
        yield [list(r) for r in xs], list(ys)


print(f"const SEQ_LEARN_X: [f64; {P}] = [{', '.join(repr(float(v)) for v in SEQ_LEARN_X)}];")
print(f"const SEQ_LEARN_Y: f64 = {SEQ_LEARN_Y!r};")
print("/// (is_unlearn, index) per step; learn steps push (SEQ_LEARN_X, SEQ_LEARN_Y).")
print(f"const SEQ_OPS: [(bool, usize); {len(SEQ)}] = [" + ", ".join(
    f"({'true' if op == 'unlearn' else 'false'}, {idx if idx is not None else 0})"
    for op, idx in SEQ
) + "];")
for name, fn in (("KNN", knn_coefs), ("RIDGE", ridge_coefs)):
    pvals, regs = [], []
    for xs, ys in seq_states():
        coefs, a, b = fn(xs, ys, PROBES[0])
        assert tie_margin(coefs, a, b, CAND_Y[0]) > 1e-7, "seq tie too close"
        pvals.append(p_value(coefs, a, b, CAND_Y[0]))
        regs.append(region(coefs, a, b, EPSES[0]))
    print(f"/// Per-step goldens at probe 0 after each SEQ_OPS step (eps = {EPSES[0]}).")
    print(f"#[rustfmt::skip]")
    print(f"const SEQ_{name}_REGIONS: [&[(f64, f64)]; {len(SEQ)}] = [")
    for ivs in regs:
        body = ", ".join(f"({repr(lo)}, {repr(hi)})" for lo, hi in ivs)
        print(f"    &[{body}],")
    print("];")
    print(
        f"const SEQ_{name}_PVALS: [f64; {len(SEQ)}] = "
        f"[{', '.join(repr(p) for p in pvals)}];"
    )
print("// ---- end GENERATED ----")
