//! Integration tests for the AOT bridge: Rust loads the HLO artifacts
//! produced by `make artifacts` (JAX/Pallas, interpret mode) and checks
//! the PJRT-executed numerics against the native Rust implementations.
//!
//! These tests require `artifacts/` to exist; they are skipped (with a
//! loud message) when it does not, so `cargo test` works pre-`make`.

use exact_cp::cp::measure::CpMeasure;
use exact_cp::data::{make_classification, ClassificationSpec, Rng};
use exact_cp::linalg::engine::{DistEngine, NativeEngine};
use exact_cp::measures::knn::KnnOptimized;
use exact_cp::runtime::{PjrtEngine, PjrtRuntime};
use std::sync::Arc;

fn runtime() -> Option<Arc<PjrtRuntime>> {
    match PjrtRuntime::open("artifacts") {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("SKIP: artifacts not available ({e}); run `make artifacts`");
            None
        }
    }
}

fn rand_rows(n: usize, p: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed_from(seed);
    (0..n * p).map(|_| rng.normal()).collect()
}

#[test]
fn dist_row_matches_native() {
    let Some(rt) = runtime() else { return };
    for (n, p) in [(10, 5), (200, 30), (256, 32), (300, 30), (1024, 32)] {
        let rows = rand_rows(n, p, 1);
        let x = rand_rows(1, p, 2);
        let got = rt.dist_row_sq_f32(&x, &rows, p).unwrap();
        let mut want = vec![0.0; n];
        NativeEngine.dist_row_sq(&x, &rows, p, &mut want);
        assert_eq!(got.len(), n);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                "n={n} p={p}: {g} vs {w}"
            );
        }
    }
}

#[test]
fn kde_row_matches_native() {
    let Some(rt) = runtime() else { return };
    let (n, p, h2) = (100, 30, 2.0);
    let rows = rand_rows(n, p, 3);
    let x = rand_rows(1, p, 4);
    let got = rt.kde_row_f32(&x, &rows, p, h2).unwrap();
    let mut want = vec![0.0; n];
    NativeEngine.kde_row(&x, &rows, p, h2, &mut want);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-4, "{g} vs {w}");
    }
}

#[test]
fn knn_update_kernel_matches_rule() {
    let Some(rt) = runtime() else { return };
    let (n, p, k) = (120, 30, 5usize);
    let rows = rand_rows(n, p, 5);
    let x = rand_rows(1, p, 6);
    // native distances for the oracle
    let mut d2 = vec![0.0; n];
    NativeEngine.dist_row_sq(&x, &rows, p, &mut d2);
    let d: Vec<f64> = d2.iter().map(|v| v.sqrt()).collect();
    let mut rng = Rng::seed_from(7);
    let alpha_prov: Vec<f64> = (0..n).map(|_| rng.f64() * 3.0).collect();
    let delta_k: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0).collect();
    let same: Vec<f64> = (0..n).map(|_| (rng.below(2)) as f64).collect();
    let _ = k;
    let got = rt
        .knn_update_f32(&x, &rows, p, &alpha_prov, &delta_k, &same)
        .unwrap();
    for i in 0..n {
        let want = if same[i] > 0.5 && d[i] < delta_k[i] {
            alpha_prov[i] - delta_k[i] + d[i]
        } else {
            alpha_prov[i]
        };
        assert!(
            (got[i] - want).abs() < 1e-3,
            "i={i}: {} vs {want} (d={} delta={})",
            got[i],
            d[i],
            delta_k[i]
        );
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(rt) = runtime() else { return };
    let rows = rand_rows(50, 30, 8);
    let x = rand_rows(1, 30, 9);
    assert_eq!(rt.compiled_count(), 0);
    rt.dist_row_sq_f32(&x, &rows, 30).unwrap();
    assert_eq!(rt.compiled_count(), 1);
    rt.dist_row_sq_f32(&x, &rows, 30).unwrap();
    assert_eq!(rt.compiled_count(), 1, "second call must hit the cache");
}

#[test]
fn optimized_knn_cp_agrees_across_backends() {
    // The same optimized algorithm, native vs PJRT distance engine:
    // p-values agree (f32 boundary => tolerate tie flips on ~1e-6 gaps).
    let Some(rt) = runtime() else { return };
    let ds = make_classification(
        &ClassificationSpec {
            n_samples: 60,
            ..Default::default()
        },
        11,
    );
    let mut native = KnnOptimized::new(5, true);
    let mut pjrt = KnnOptimized::with_engine(
        5,
        true,
        Arc::new(PjrtEngine::new(rt)),
    );
    native.fit(&ds);
    pjrt.fit(&ds);
    let probe = make_classification(
        &ClassificationSpec {
            n_samples: 8,
            ..Default::default()
        },
        12,
    );
    for i in 0..probe.n() {
        for y in 0..2 {
            let a = native.scores(probe.row(i), y);
            let b = pjrt.scores(probe.row(i), y);
            for (u, v) in a.train.iter().zip(&b.train) {
                let both_inf = u.is_infinite() && v.is_infinite();
                assert!(
                    both_inf || (u - v).abs() < 1e-3 * (1.0 + u.abs()),
                    "{u} vs {v}"
                );
            }
        }
    }
}
