//! Configuration system: TOML-lite files + CLI overrides.
//!
//! One config drives the launcher (`repro serve`), the experiment
//! drivers (`repro experiment <id>`), and the examples, so runs are
//! declarative and reproducible. Parsing is in-tree
//! ([`crate::util::toml_lite`]) — the offline environment ships no
//! serde/toml crates.

use crate::util::toml_lite::{self, Doc};
use crate::Result;

/// Which nonconformity measure a deployment/experiment uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MeasureKind {
    Knn,
    SimplifiedKnn,
    Kde,
    LsSvm,
    RandomForest,
}

impl MeasureKind {
    pub fn all() -> [MeasureKind; 5] {
        [
            MeasureKind::Knn,
            MeasureKind::SimplifiedKnn,
            MeasureKind::Kde,
            MeasureKind::LsSvm,
            MeasureKind::RandomForest,
        ]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            MeasureKind::Knn => "knn",
            MeasureKind::SimplifiedKnn => "simplified-knn",
            MeasureKind::Kde => "kde",
            MeasureKind::LsSvm => "lssvm",
            MeasureKind::RandomForest => "rf",
        }
    }
}

impl std::str::FromStr for MeasureKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "knn" => MeasureKind::Knn,
            "simplified-knn" | "sknn" => MeasureKind::SimplifiedKnn,
            "kde" => MeasureKind::Kde,
            "lssvm" | "ls-svm" => MeasureKind::LsSvm,
            "rf" | "random-forest" | "bootstrap" => MeasureKind::RandomForest,
            other => anyhow::bail!("unknown measure {other:?}"),
        })
    }
}

/// Which CP regressor a regression deployment uses (§8).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RegressorKind {
    /// optimized k-NN regressor (precomputed neighbour statistics)
    Knn,
    /// standard Papadopoulos et al. (2011) k-NN regressor
    KnnStandard,
    /// ridge RRCM with Sherman–Morrison updates
    Ridge,
}

impl RegressorKind {
    pub fn all() -> [RegressorKind; 3] {
        [
            RegressorKind::Knn,
            RegressorKind::KnnStandard,
            RegressorKind::Ridge,
        ]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            RegressorKind::Knn => "knn-reg",
            RegressorKind::KnnStandard => "knn-reg-standard",
            RegressorKind::Ridge => "ridge",
        }
    }
}

impl std::str::FromStr for RegressorKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "knn-reg" | "knn-regression" => RegressorKind::Knn,
            "knn-reg-standard" => RegressorKind::KnnStandard,
            "ridge" | "rrcm" => RegressorKind::Ridge,
            other => anyhow::bail!("unknown regressor {other:?}"),
        })
    }
}

/// Measure hyperparameters (paper App. E defaults).
#[derive(Clone, Debug)]
pub struct MeasureConfig {
    /// k for the nearest-neighbour measures
    pub k: usize,
    /// KDE bandwidth
    pub h: f64,
    /// LS-SVM ridge parameter
    pub rho: f64,
    /// bootstrap ensemble size
    pub b: usize,
    /// RFF feature dimension (0 = linear kernel)
    pub rff_dim: usize,
    /// RFF kernel bandwidth
    pub rff_gamma: f64,
}

impl Default for MeasureConfig {
    fn default() -> Self {
        MeasureConfig {
            k: 15,
            h: 1.0,
            rho: 1.0,
            b: 10,
            rff_dim: 0,
            rff_gamma: 0.5,
        }
    }
}

/// Observability configuration (`[serve.obs]`). Everything here is off
/// the exact-value path: tracing and validity monitoring read timings
/// and finished outputs only (EXACTNESS.md).
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// enable stage-level span tracing at startup (`op:"trace"` /
    /// `--trace-out` still work later; this only sets the initial
    /// state)
    pub trace: bool,
    /// trace ring-buffer capacity, in events
    pub ring_capacity: usize,
    /// epsilons the per-deployment validity monitors track (empty =
    /// the monitor's built-in defaults)
    pub epsilons: Vec<f64>,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            trace: false,
            ring_capacity: 65_536,
            epsilons: Vec::new(),
        }
    }
}

/// One `[serve.deployment.<name>]` block: a deployment trained at
/// startup with its *own* hyperparameters instead of the process-wide
/// `[measure]` block. `kind` is a measure name ("knn", "kde", ...) for
/// classification or a regressor name ("ridge", "knn-reg", ...) for
/// regression; unset hyperparameters inherit the global `[measure]`
/// values.
#[derive(Clone, Debug)]
pub struct DeploymentSpec {
    pub name: String,
    pub kind: String,
    pub measure: MeasureConfig,
}

/// Serving-coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// max requests drained per batch
    pub max_batch: usize,
    /// max time a request waits for batching (microseconds)
    pub max_wait_us: u64,
    /// significance level used when a request does not specify one
    pub default_epsilon: f64,
    /// worker threads processing batches
    pub workers: usize,
    /// bounded queue depth before backpressure rejects
    pub queue_depth: usize,
    /// scoped worker threads inside one distance-matrix launch
    /// (1 = serial; any value yields bit-identical output)
    pub dist_workers: usize,
    /// observability knobs (`[serve.obs]`)
    pub obs: ObsConfig,
    /// per-deployment specs (`[serve.deployment.<name>]` blocks)
    pub deployments: Vec<DeploymentSpec>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            max_batch: 32,
            max_wait_us: 2_000,
            default_epsilon: 0.1,
            workers: 2,
            queue_depth: 1024,
            dist_workers: 1,
            obs: ObsConfig::default(),
            deployments: Vec::new(),
        }
    }
}

/// Experiment-harness configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// training sizes (log grid); empty = driver default
    pub train_sizes: Vec<usize>,
    /// test points per configuration
    pub n_test: usize,
    /// repeats (seeds) per configuration
    pub seeds: u64,
    /// per-point timeout in seconds (paper: 10 h; scaled default here)
    pub timeout_s: f64,
    /// output directory for CSV/markdown reports
    pub out_dir: String,
    /// use the paper's full-size grids (hours of runtime)
    pub paper_scale: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            train_sizes: Vec::new(),
            n_test: 10,
            seeds: 3,
            timeout_s: 20.0,
            out_dir: "results".into(),
            paper_scale: false,
        }
    }
}

/// Top-level configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub measure: MeasureConfig,
    pub serve: ServeConfig,
    pub experiment: ExperimentConfig,
    /// PJRT backend for distance kernels (native when false)
    pub use_pjrt: bool,
    /// artifact directory for AOT HLO modules
    pub artifacts_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            measure: MeasureConfig::default(),
            serve: ServeConfig::default(),
            experiment: ExperimentConfig::default(),
            use_pjrt: false,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl Config {
    /// Build from a parsed TOML-lite document, defaulting every field.
    pub fn from_doc(doc: &Doc) -> Config {
        let d = Config::default();
        let measure = MeasureConfig {
            k: doc.usize_or("measure.k", d.measure.k),
            h: doc.f64_or("measure.h", d.measure.h),
            rho: doc.f64_or("measure.rho", d.measure.rho),
            b: doc.usize_or("measure.b", d.measure.b),
            rff_dim: doc.usize_or("measure.rff_dim", d.measure.rff_dim),
            rff_gamma: doc.f64_or("measure.rff_gamma", d.measure.rff_gamma),
        };
        // [serve.deployment.<name>] blocks: per-deployment
        // hyperparameters inheriting the global [measure] values
        let deployments = doc
            .subsections("serve.deployment")
            .into_iter()
            .map(|name| {
                let p = format!("serve.deployment.{name}");
                DeploymentSpec {
                    measure: MeasureConfig {
                        k: doc.usize_or(&format!("{p}.k"), measure.k),
                        h: doc.f64_or(&format!("{p}.h"), measure.h),
                        rho: doc.f64_or(&format!("{p}.rho"), measure.rho),
                        b: doc.usize_or(&format!("{p}.b"), measure.b),
                        rff_dim: doc
                            .usize_or(&format!("{p}.rff_dim"), measure.rff_dim),
                        rff_gamma: doc.f64_or(
                            &format!("{p}.rff_gamma"),
                            measure.rff_gamma,
                        ),
                    },
                    kind: doc.str_or(&format!("{p}.kind"), "simplified-knn"),
                    name,
                }
            })
            .collect();
        Config {
            serve: ServeConfig {
                addr: doc.str_or("serve.addr", &d.serve.addr),
                max_batch: doc.usize_or("serve.max_batch", d.serve.max_batch),
                max_wait_us: doc.u64_or("serve.max_wait_us", d.serve.max_wait_us),
                default_epsilon: doc
                    .f64_or("serve.default_epsilon", d.serve.default_epsilon),
                workers: doc.usize_or("serve.workers", d.serve.workers),
                queue_depth: doc.usize_or("serve.queue_depth", d.serve.queue_depth),
                dist_workers: doc
                    .usize_or("serve.dist_workers", d.serve.dist_workers),
                obs: ObsConfig {
                    trace: doc.bool_or("serve.obs.trace", d.serve.obs.trace),
                    ring_capacity: doc.usize_or(
                        "serve.obs.ring_capacity",
                        d.serve.obs.ring_capacity,
                    ),
                    epsilons: doc.f64_array("serve.obs.epsilons"),
                },
                deployments,
            },
            measure,
            experiment: ExperimentConfig {
                train_sizes: doc.usize_array("experiment.train_sizes"),
                n_test: doc.usize_or("experiment.n_test", d.experiment.n_test),
                seeds: doc.u64_or("experiment.seeds", d.experiment.seeds),
                timeout_s: doc.f64_or("experiment.timeout_s", d.experiment.timeout_s),
                out_dir: doc.str_or("experiment.out_dir", &d.experiment.out_dir),
                paper_scale: doc
                    .bool_or("experiment.paper_scale", d.experiment.paper_scale),
            },
            use_pjrt: doc.bool_or("use_pjrt", d.use_pjrt),
            artifacts_dir: doc.str_or("artifacts_dir", &d.artifacts_dir),
        }
    }

    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::from_doc(&toml_lite::parse(&text)?))
    }

    pub fn load_or_default(path: Option<&str>) -> Result<Config> {
        match path {
            Some(p) => Self::load(p),
            None => Ok(Config::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_appendix_e() {
        let c = MeasureConfig::default();
        assert_eq!(c.k, 15);
        assert_eq!(c.h, 1.0);
        assert_eq!(c.rho, 1.0);
        assert_eq!(c.b, 10);
    }

    #[test]
    fn partial_doc_keeps_defaults() {
        let doc = toml_lite::parse(
            r#"
            use_pjrt = true
            [measure]
            k = 7
            [serve]
            max_batch = 8
            dist_workers = 4
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc);
        assert!(c.use_pjrt);
        assert_eq!(c.measure.k, 7);
        assert_eq!(c.measure.b, 10);
        assert_eq!(c.serve.max_batch, 8);
        assert_eq!(c.serve.workers, 2);
        assert_eq!(c.serve.dist_workers, 4);
        assert_eq!(ServeConfig::default().dist_workers, 1);
    }

    #[test]
    fn obs_block_parses_with_defaults() {
        let c = Config::from_doc(&toml_lite::parse("").unwrap());
        assert!(!c.serve.obs.trace);
        assert_eq!(c.serve.obs.ring_capacity, 65_536);
        assert!(c.serve.obs.epsilons.is_empty());
        assert!(c.serve.deployments.is_empty());
        let doc = toml_lite::parse(
            r#"
            [serve.obs]
            trace = true
            ring_capacity = 1024
            epsilons = [0.05, 0.1]
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc);
        assert!(c.serve.obs.trace);
        assert_eq!(c.serve.obs.ring_capacity, 1024);
        assert_eq!(c.serve.obs.epsilons, vec![0.05, 0.1]);
    }

    #[test]
    fn deployment_blocks_inherit_global_measure() {
        let doc = toml_lite::parse(
            r#"
            [measure]
            k = 9
            rho = 2.0
            [serve.deployment.fast]
            kind = "simplified-knn"
            k = 3
            [serve.deployment.rrcm]
            kind = "ridge"
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc);
        assert_eq!(c.serve.deployments.len(), 2);
        let fast = &c.serve.deployments[0];
        assert_eq!(fast.name, "fast");
        assert_eq!(fast.kind, "simplified-knn");
        assert_eq!(fast.measure.k, 3, "per-deployment override");
        assert_eq!(fast.measure.rho, 2.0, "inherits global [measure]");
        let rrcm = &c.serve.deployments[1];
        assert_eq!(rrcm.kind, "ridge");
        assert_eq!(rrcm.measure.k, 9, "inherits global k");
    }

    #[test]
    fn measure_kind_parses() {
        use std::str::FromStr;
        assert_eq!(MeasureKind::from_str("knn").unwrap(), MeasureKind::Knn);
        assert_eq!(
            MeasureKind::from_str("random-forest").unwrap(),
            MeasureKind::RandomForest
        );
        assert!(MeasureKind::from_str("bogus").is_err());
    }

    #[test]
    fn regressor_kind_round_trips() {
        use std::str::FromStr;
        for kind in RegressorKind::all() {
            assert_eq!(RegressorKind::from_str(kind.as_str()).unwrap(), kind);
        }
        assert_eq!(
            RegressorKind::from_str("rrcm").unwrap(),
            RegressorKind::Ridge
        );
        assert!(RegressorKind::from_str("bogus").is_err());
    }
}
