//! `repro` — the exact-cp launcher.
//!
//! ```text
//! repro experiment <id>|all [--config F] [--out DIR] [--sizes a,b,c]
//!                  [--seeds K] [--n-test M] [--timeout S] [--paper-scale]
//! repro serve      [--config F] [--addr A] [--n N] [--measures knn,kde]
//!                  [--use-pjrt]
//! repro predict    [--measure M] [--n N] [--eps E] [--use-pjrt]
//! repro artifacts  [--dir DIR]            # inspect the AOT manifest
//! repro selfcheck                          # exactness spot-check
//! ```
//!
//! Argument parsing is in-tree (the offline build has no clap).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use exact_cp::bench_harness::{self, ALL_EXPERIMENTS};
use exact_cp::config::{Config, MeasureKind, RegressorKind};
use exact_cp::coordinator::factory::{
    build_measure, build_standard_measure, deployment_from_spec, select_engine,
};
use exact_cp::coordinator::server::{serve, Server};
use exact_cp::coordinator::state::{Deployment, Registry};
use exact_cp::cp::pvalue::p_value;
use exact_cp::data::{make_classification, make_regression, ClassificationSpec, RegressionSpec};
use exact_cp::runtime::PjrtRuntime;

/// Minimal flag parser: positional args + `--key value` + `--flag`.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

const BOOL_FLAGS: [&str; 4] = ["paper-scale", "use-pjrt", "help", "trace"];

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if BOOL_FLAGS.contains(&key)
                    || i + 1 >= argv.len()
                    || argv[i + 1].starts_with("--")
                {
                    flags.insert(key.to_string(), "true".into());
                    i += 1;
                } else {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = Config::load_or_default(args.get("config"))?;
    if let Some(sizes) = args.get("sizes") {
        cfg.experiment.train_sizes = sizes
            .split(',')
            .map(|s| s.trim().parse().context("bad --sizes"))
            .collect::<Result<_>>()?;
    }
    if let Some(v) = args.get("seeds") {
        cfg.experiment.seeds = v.parse()?;
    }
    if let Some(v) = args.get("n-test") {
        cfg.experiment.n_test = v.parse()?;
    }
    if let Some(v) = args.get("timeout") {
        cfg.experiment.timeout_s = v.parse()?;
    }
    if let Some(v) = args.get("out") {
        cfg.experiment.out_dir = v.into();
    }
    if let Some(v) = args.get("k") {
        cfg.measure.k = v.parse()?;
    }
    if args.has("paper-scale") {
        cfg.experiment.paper_scale = true;
    }
    if args.has("use-pjrt") {
        cfg.use_pjrt = true;
    }
    Ok(cfg)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv);
    match args.positional.first().map(|s| s.as_str()) {
        Some("experiment") => cmd_experiment(&args),
        Some("serve") => cmd_serve(&args),
        Some("predict") => cmd_predict(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("selfcheck") => cmd_selfcheck(&args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
repro — Exact Optimization of Conformal Predictors (ICML 2021 reproduction)

USAGE:
  repro experiment <id>|all [--out DIR] [--sizes a,b,c] [--seeds K]
                   [--n-test M] [--timeout S] [--paper-scale] [--config F]
      ids: fig2 fig3 fig4 fig5 fig6 table1 table2 table3 fuzziness iid
  repro serve   [--addr HOST:PORT] [--n N] [--measures knn,kde,...]
                [--regressors knn-reg,ridge,...] [--use-pjrt] [--config F]
                [--trace] [--trace-out FILE]
      --trace enables the stage-span ring (dump via op \"trace\");
      --trace-out additionally streams spans to FILE as JSON lines;
      [serve.deployment.X] config blocks add deployments with their
      own hyperparameters (kind, k, rho, h, ...)
  repro predict [--measure M] [--n N] [--eps E] [--use-pjrt]
  repro artifacts [--dir DIR]
  repro selfcheck
";

fn cmd_experiment(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let ids: Vec<&str> = if id == "all" {
        ALL_EXPERIMENTS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        println!("== experiment {id} ==");
        let t0 = std::time::Instant::now();
        let report = bench_harness::run_experiment(id, &cfg)?;
        println!(
            "== {id}: {} rows in {:.1}s ==\n",
            report.rows.len(),
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n: usize = args.get("n").map(|v| v.parse()).transpose()?.unwrap_or(1000);
    let measures = args.get("measures").unwrap_or("simplified-knn,kde");
    let addr = args
        .get("addr")
        .map(|s| s.to_string())
        .unwrap_or_else(|| cfg.serve.addr.clone());

    let engine =
        select_engine(cfg.use_pjrt, &cfg.artifacts_dir, cfg.serve.dist_workers);
    let ds = make_classification(
        &ClassificationSpec {
            n_samples: n,
            ..Default::default()
        },
        1,
    );
    let registry = Arc::new(Registry::new());
    let mut n_deployments = 0;
    for name in measures.split(',') {
        let kind: MeasureKind = name.trim().parse()?;
        println!("training deployment {name} on n={n}...");
        registry.insert(Deployment::train(
            name.trim(),
            kind,
            &cfg.measure,
            &ds,
            Some(engine.clone()),
        ));
        n_deployments += 1;
    }
    // regression deployments (served via op "predict_region")
    if let Some(regressors) = args.get("regressors") {
        let rds = make_regression(
            &RegressionSpec {
                n_samples: n,
                n_features: 10,
                n_informative: 5,
                noise: 5.0,
            },
            1,
        );
        for name in regressors.split(',') {
            let kind: RegressorKind = name.trim().parse()?;
            println!("training regression deployment {name} on n={n}...");
            registry.insert(Deployment::train_regression(
                name.trim(),
                kind,
                &cfg.measure,
                &rds,
                Some(engine.clone()),
            ));
            n_deployments += 1;
        }
    }
    // [serve.deployment.X] config blocks: named deployments with their
    // own kind and hyperparameters (satellite of the obs work: lets two
    // k-NN deployments serve different k / ridge rho side by side)
    if !cfg.serve.deployments.is_empty() {
        let rds = make_regression(
            &RegressionSpec {
                n_samples: n,
                n_features: 10,
                n_informative: 5,
                noise: 5.0,
            },
            1,
        );
        for spec in &cfg.serve.deployments {
            println!(
                "training deployment {} (kind {}) on n={n}...",
                spec.name, spec.kind
            );
            registry.insert(deployment_from_spec(
                spec,
                &ds,
                &rds,
                Some(engine.clone()),
            )?);
            n_deployments += 1;
        }
    }
    let mut serve_cfg = cfg.serve.clone();
    serve_cfg.addr = addr.clone();
    if args.has("trace") || args.get("trace-out").is_some() {
        serve_cfg.obs.trace = true;
    }
    let server = Arc::new(Server::start(serve_cfg, registry));
    // spawned after Server::start so the ring exists; dropped (final
    // drain + join) when serve() returns
    let _trace_writer = match args.get("trace-out") {
        Some(path) => {
            let path = std::path::Path::new(path);
            Some(
                exact_cp::obs::trace::JsonlWriter::spawn(path).with_context(
                    || format!("creating trace file {}", path.display()),
                )?,
            )
        }
        None => None,
    };
    let listener = std::net::TcpListener::bind(&addr)
        .with_context(|| format!("binding {addr}"))?;
    println!(
        "serving {n_deployments} deployment(s) on {addr} (engine: {}) — \
         JSON lines; send {{\"op\":\"shutdown\"}} to stop",
        engine.name(),
    );
    serve(server, listener)
}

fn cmd_predict(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let kind: MeasureKind = args.get("measure").unwrap_or("simplified-knn").parse()?;
    let n: usize = args.get("n").map(|v| v.parse()).transpose()?.unwrap_or(500);
    let eps: f64 = args.get("eps").map(|v| v.parse()).transpose()?.unwrap_or(0.1);
    let engine =
        select_engine(cfg.use_pjrt, &cfg.artifacts_dir, cfg.serve.dist_workers);

    let ds = make_classification(
        &ClassificationSpec {
            n_samples: n + 5,
            ..Default::default()
        },
        1,
    );
    let mut rng = exact_cp::data::Rng::seed_from(2);
    let (train, test) = ds.split(n, &mut rng);
    let mut m = build_measure(kind, &cfg.measure, Some(engine));
    let t0 = std::time::Instant::now();
    m.fit(&train);
    println!("trained {} on n={n} in {:.3}s", m.name(), t0.elapsed().as_secs_f64());
    for i in 0..test.n() {
        let t0 = std::time::Instant::now();
        let ps: Vec<f64> = (0..train.n_labels)
            .map(|y| p_value(&m.scores(test.row(i), y)))
            .collect();
        let set: Vec<usize> = ps
            .iter()
            .enumerate()
            .filter(|(_, &p)| p > eps)
            .map(|(y, _)| y)
            .collect();
        println!(
            "test[{i}] true={} p_values={ps:?} set(eps={eps})={set:?} \
             ({:.2}ms)",
            test.y[i],
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.get("dir").unwrap_or("artifacts");
    let rt = PjrtRuntime::open(dir)?;
    println!(
        "{} artifacts in {dir} (PJRT CPU client ready)",
        rt.manifest().len()
    );
    for (name, info) in &rt.manifest().artifacts {
        println!("  {name:<28} {:?}", info.arg_shapes);
    }
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ds = make_classification(
        &ClassificationSpec {
            n_samples: 60,
            ..Default::default()
        },
        1,
    );
    let probe = make_classification(
        &ClassificationSpec {
            n_samples: 5,
            ..Default::default()
        },
        2,
    );
    println!("exactness spot-check (optimized vs standard p-values):");
    let mut mc = cfg.measure.clone();
    mc.b = 5;
    for kind in [
        MeasureKind::SimplifiedKnn,
        MeasureKind::Knn,
        MeasureKind::Kde,
        MeasureKind::LsSvm,
    ] {
        let mut s = build_standard_measure(kind, &mc);
        let mut o = build_measure(kind, &mc, None);
        s.fit(&ds);
        o.fit(&ds);
        let mut max_dp: f64 = 0.0;
        for i in 0..probe.n() {
            for y in 0..2 {
                let ps = p_value(&s.scores(probe.row(i), y));
                let po = p_value(&o.scores(probe.row(i), y));
                max_dp = max_dp.max((ps - po).abs());
            }
        }
        println!(
            "  {:<16} max |Δp| = {max_dp:.2e}  {}",
            kind.as_str(),
            if max_dp < 1e-12 { "EXACT" } else { "MISMATCH" }
        );
        if max_dp >= 1e-12 {
            bail!("exactness violated for {}", kind.as_str());
        }
    }
    println!("selfcheck OK");
    Ok(())
}
