//! Inductive Conformal Prediction — Algorithm 2 (App. A).
//!
//! The paper's computational baseline: train the measure once on a
//! proper-training subset, score the calibration remainder, and compute
//! each test p-value against the *sorted* calibration scores (binary
//! search — an implementation detail the paper's O(n - t) bound allows
//! us to beat; it does not change who wins).

use crate::data::{Dataset, Label};

/// A measure usable inductively: fit on the proper training set, then
/// score arbitrary examples against it.
pub trait IcpMeasure: Send {
    fn name(&self) -> String;
    fn fit(&mut self, proper: &Dataset);
    /// alpha = A((x, y); Z_train)
    fn score(&self, x: &[f64], y: Label) -> f64;
}

/// Inductive CP classifier.
pub struct Icp<M: IcpMeasure> {
    measure: M,
    /// calibration scores, sorted ascending
    calib: Vec<f64>,
    n_labels: usize,
}

impl<M: IcpMeasure> Icp<M> {
    /// CALIBRATE(): split at `t`, fit on the proper training set, score
    /// the calibration set under true labels.
    pub fn calibrate(mut measure: M, ds: &Dataset, t: usize) -> Self {
        assert!(t >= 1 && t < ds.n(), "need 1 <= t < n");
        let (proper, calib_set) = ds.split_at(t);
        measure.fit(&proper);
        let mut calib: Vec<f64> = (0..calib_set.n())
            .map(|i| measure.score(calib_set.row(i), calib_set.y[i]))
            .collect();
        calib.sort_unstable_by(|a, b| a.total_cmp(b));
        Icp {
            measure,
            calib,
            n_labels: ds.n_labels,
        }
    }

    /// COMPUTE_PVALUE(): p = (#{alpha_i >= alpha} + 1) / (c + 1).
    pub fn p_value_for(&self, x: &[f64], y: Label) -> f64 {
        let alpha = self.measure.score(x, y);
        // first index with calib[idx] >= alpha
        let idx = self.calib.partition_point(|&a| a < alpha);
        let ge = self.calib.len() - idx;
        (ge + 1) as f64 / (self.calib.len() + 1) as f64
    }

    pub fn p_values(&self, x: &[f64]) -> Vec<f64> {
        (0..self.n_labels)
            .map(|y| self.p_value_for(x, y))
            .collect()
    }

    pub fn predict_set(&self, x: &[f64], eps: f64) -> Vec<Label> {
        self.p_values(x)
            .iter()
            .enumerate()
            .filter(|(_, &p)| p > eps)
            .map(|(y, _)| y)
            .collect()
    }

    pub fn calibration_size(&self) -> usize {
        self.calib.len()
    }

    pub fn measure(&self) -> &M {
        &self.measure
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// alpha = |x0 - y| : label 0 conforms near 0, label 1 near 1.
    struct Toy;
    impl IcpMeasure for Toy {
        fn name(&self) -> String {
            "toy".into()
        }
        fn fit(&mut self, _proper: &Dataset) {}
        fn score(&self, x: &[f64], y: Label) -> f64 {
            (x[0] - y as f64).abs()
        }
    }

    fn ds() -> Dataset {
        // 6 pts: x0 = label +- 0.1
        let x = vec![0.1, -0.1, 0.9, 1.1, 0.05, 0.95];
        let y = vec![0, 0, 1, 1, 0, 1];
        Dataset::new(x, y, 1, 2)
    }

    #[test]
    fn calibration_and_pvalues() {
        let icp = Icp::calibrate(Toy, &ds(), 2);
        assert_eq!(icp.calibration_size(), 4);
        // a clean label-0 point: alpha=0, all 4 calib scores >= 0
        let p0 = icp.p_value_for(&[0.0], 0);
        assert_eq!(p0, 1.0);
        // absurd point: alpha large, nothing >=
        let p1 = icp.p_value_for(&[5.0], 0);
        assert_eq!(p1, 1.0 / 5.0);
    }

    #[test]
    fn prediction_set_behaviour() {
        let icp = Icp::calibrate(Toy, &ds(), 2);
        let set = icp.predict_set(&[0.02], 0.3);
        assert_eq!(set, vec![0]);
    }

    #[test]
    #[should_panic]
    fn rejects_degenerate_split() {
        let _ = Icp::calibrate(Toy, &ds(), 6);
    }
}
