//! Full (transductive) conformal classifier — Algorithm 1 of the paper,
//! generic over the nonconformity measure.

use crate::cp::measure::CpMeasure;
use crate::cp::pvalue::p_value;
use crate::data::{Dataset, Label};

/// A full CP classifier wrapping a [`CpMeasure`].
///
/// For a test object x it computes one p-value per candidate label by
/// running the measure's LOO scoring (Algorithm 1), and emits the
/// prediction set Gamma^eps = { y : p_(x,y) > eps }, which contains the
/// true label with probability >= 1 - eps under exchangeability.
pub struct FullCp<M: CpMeasure> {
    measure: M,
    n_labels: usize,
}

/// Forced (point) prediction with its confidence/credibility pair.
#[derive(Clone, Debug, PartialEq)]
pub struct ForcedPrediction {
    /// argmax-p label
    pub label: Label,
    /// largest p-value — low credibility flags an outlier test object
    pub credibility: f64,
    /// 1 - (second largest p-value)
    pub confidence: f64,
}

impl<M: CpMeasure> FullCp<M> {
    /// Fit the measure on the training set. For optimized measures this
    /// runs the paper's precomputation (Table 1 "Train" column); for
    /// standard measures it is O(1) bookkeeping.
    pub fn train(mut measure: M, ds: &Dataset) -> Self {
        measure.fit(ds);
        FullCp {
            measure,
            n_labels: ds.n_labels,
        }
    }

    /// One conformal p-value per label, in label order.
    pub fn p_values(&self, x: &[f64]) -> Vec<f64> {
        (0..self.n_labels)
            .map(|y| p_value(&self.measure.scores(x, y)))
            .collect()
    }

    /// [`p_values`] for a whole batch of test objects through ONE
    /// [`CpMeasure::scores_batch`] call: one row of per-label p-values
    /// per test object. Equal to calling [`p_values`] per object (the
    /// measure's batch contract is bit-for-bit), but measures with a
    /// specialized batch path compute each object's distance/kernel row
    /// once instead of once per label.
    ///
    /// [`p_values`]: FullCp::p_values
    pub fn p_values_batch(&self, xs: &[&[f64]]) -> Vec<Vec<f64>> {
        crate::cp::pvalue::p_value_rows(&self.measure, xs, self.n_labels)
    }

    /// p-value for a single (x, y) pairing.
    pub fn p_value_for(&self, x: &[f64], y: Label) -> f64 {
        p_value(&self.measure.scores(x, y))
    }

    /// The prediction set Gamma^eps.
    pub fn predict_set(&self, x: &[f64], eps: f64) -> Vec<Label> {
        set_from_p_values(&self.p_values(x), eps)
    }

    /// Prediction sets for a whole batch of test objects, via one
    /// [`CpMeasure::scores_batch`] call (see [`FullCp::p_values_batch`]).
    pub fn predict_sets(&self, xs: &[&[f64]], eps: f64) -> Vec<Vec<Label>> {
        self.p_values_batch(xs)
            .iter()
            .map(|ps| set_from_p_values(ps, eps))
            .collect()
    }

    /// Forced point prediction + credibility/confidence.
    pub fn forced(&self, x: &[f64]) -> ForcedPrediction {
        forced_from_p_values(&self.p_values(x))
    }

    /// [`FullCp::forced`] for a whole batch, via one batched scoring
    /// pass.
    pub fn forced_batch(&self, xs: &[&[f64]]) -> Vec<ForcedPrediction> {
        self.p_values_batch(xs)
            .iter()
            .map(|ps| forced_from_p_values(ps))
            .collect()
    }

    /// Access the wrapped measure (online updates, diagnostics).
    pub fn measure(&self) -> &M {
        &self.measure
    }

    pub fn measure_mut(&mut self) -> &mut M {
        &mut self.measure
    }

    pub fn n_labels(&self) -> usize {
        self.n_labels
    }
}

/// Gamma^eps from a per-label p-value row — the canonical set filter,
/// shared by [`FullCp`] and the serving coordinator.
pub fn set_from_p_values(ps: &[f64], eps: f64) -> Vec<Label> {
    ps.iter()
        .enumerate()
        .filter(|(_, &p)| p > eps)
        .map(|(y, _)| y)
        .collect()
}

/// Forced prediction from a per-label p-value row — the canonical
/// argmax (ties break to the FIRST maximal label), shared by
/// [`FullCp`] and the serving coordinator.
pub fn forced_from_p_values(ps: &[f64]) -> ForcedPrediction {
    let (mut best, mut second) = ((0usize, f64::MIN), f64::MIN);
    for (y, &p) in ps.iter().enumerate() {
        if p > best.1 {
            second = best.1;
            best = (y, p);
        } else if p > second {
            second = p;
        }
    }
    ForcedPrediction {
        label: best.0,
        credibility: best.1,
        confidence: 1.0 - second.max(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::measure::Scores;

    /// Measure where label 0 always conforms and label 1 never does.
    struct Rigged {
        n: usize,
    }
    impl CpMeasure for Rigged {
        fn name(&self) -> String {
            "rigged".into()
        }
        fn fit(&mut self, ds: &Dataset) {
            self.n = ds.n();
        }
        fn scores(&self, _x: &[f64], y: Label) -> Scores {
            let test = if y == 0 { 0.0 } else { 100.0 };
            Scores {
                train: (0..self.n).map(|i| i as f64).collect(),
                test,
            }
        }
        fn n(&self) -> usize {
            self.n
        }
        fn n_labels(&self) -> usize {
            2
        }
    }

    fn toy() -> Dataset {
        Dataset::new(vec![0.0; 8], vec![0, 0, 1, 1], 2, 2)
    }

    #[test]
    fn prediction_set_filters_by_eps() {
        let cp = FullCp::train(Rigged { n: 0 }, &toy());
        let ps = cp.p_values(&[0.0, 0.0]);
        assert_eq!(ps[0], 1.0); // all alphas >= 0
        assert_eq!(ps[1], 1.0 / 5.0); // none >= 100
        assert_eq!(cp.predict_set(&[0.0, 0.0], 0.3), vec![0]);
        assert_eq!(cp.predict_set(&[0.0, 0.0], 0.1), vec![0, 1]);
        // p-values cap at 1.0, so the most confident label survives any
        // eps < 1
        assert_eq!(cp.predict_set(&[0.0, 0.0], 0.999), vec![0]);
    }

    #[test]
    fn forced_prediction_fields() {
        let cp = FullCp::train(Rigged { n: 0 }, &toy());
        let f = cp.forced(&[0.0, 0.0]);
        assert_eq!(f.label, 0);
        assert_eq!(f.credibility, 1.0);
        assert!((f.confidence - (1.0 - 0.2)).abs() < 1e-12);
    }

    #[test]
    fn batch_apis_match_single_calls() {
        let cp = FullCp::train(Rigged { n: 0 }, &toy());
        let (a, b) = ([0.0, 0.0], [1.0, 1.0]);
        let xs: Vec<&[f64]> = vec![&a, &b];
        let rows = cp.p_values_batch(&xs);
        assert_eq!(rows.len(), 2);
        for (x, row) in xs.iter().zip(&rows) {
            assert_eq!(row, &cp.p_values(x));
        }
        assert_eq!(
            cp.predict_sets(&xs, 0.3),
            vec![cp.predict_set(&a, 0.3), cp.predict_set(&b, 0.3)]
        );
        assert_eq!(
            cp.forced_batch(&xs),
            vec![cp.forced(&a), cp.forced(&b)]
        );
        assert!(cp.p_values_batch(&[]).is_empty());
    }
}
