//! Validity / efficiency metrics (App. G).
//!
//! - empirical coverage and average prediction-set size at a given eps;
//! - *fuzziness* (Vovk et al. 2016): sum of a test point's p-values
//!   minus the largest — smaller is statistically more efficient;
//! - Welch's one-sided t-test, used by the paper to show full CP has
//!   significantly smaller fuzziness than ICP on MNIST.

/// Empirical coverage: fraction of test points whose true label is in
/// the prediction set at significance `eps`.
pub fn coverage(p_matrix: &[Vec<f64>], truth: &[usize], eps: f64) -> f64 {
    assert_eq!(p_matrix.len(), truth.len());
    let hits = p_matrix
        .iter()
        .zip(truth)
        .filter(|(ps, &y)| ps[y] > eps)
        .count();
    hits as f64 / truth.len() as f64
}

/// Size of one prediction set at significance `eps` (labels with
/// p > eps). The single-row primitive behind [`avg_set_size`], shared
/// with the online validity monitor (`obs::validity`).
pub fn set_size(ps: &[f64], eps: f64) -> usize {
    ps.iter().filter(|&&p| p > eps).count()
}

/// Is the true label inside the prediction set at significance `eps`?
/// Single-row primitive behind [`coverage`]; an out-of-range `truth`
/// counts as not covered.
pub fn covered(ps: &[f64], truth: usize, eps: f64) -> bool {
    ps.get(truth).is_some_and(|&p| p > eps)
}

/// Average prediction-set size at significance `eps`.
pub fn avg_set_size(p_matrix: &[Vec<f64>], eps: f64) -> f64 {
    let total: usize = p_matrix.iter().map(|ps| set_size(ps, eps)).sum();
    total as f64 / p_matrix.len() as f64
}

/// Fuzziness of one test point's p-values: sum minus max.
pub fn fuzziness(ps: &[f64]) -> f64 {
    // EXACT-ALLOW: EXACT001 reporting metric, not a score path; the
    // fixed left-to-right Iterator::sum order is itself the spec.
    let sum: f64 = ps.iter().sum();
    // EXACT-ALLOW: EXACT002 max is an exact lattice op (no rounding).
    let max = ps.iter().cloned().fold(f64::MIN, f64::max);
    sum - max
}

/// Mean and (sample) std of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    // EXACT-ALLOW: EXACT001 reporting statistic (App. G tables), not
    // compared bitwise against any naive baseline.
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    // EXACT-ALLOW: EXACT001 same: reporting-only variance.
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// Welch's one-sided t-test for H0: mean(a) >= mean(b) (i.e. the
/// alternative is "a has *smaller* mean than b"). Returns (t, p).
///
/// App. G usage: a = CP fuzziness, b = ICP fuzziness; small p rejects
/// "ICP is better", i.e. CP is significantly more efficient.
pub fn welch_one_sided(a: &[f64], b: &[f64]) -> (f64, f64) {
    let (ma, sa) = mean_std(a);
    let (mb, sb) = mean_std(b);
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let va = sa * sa / na;
    let vb = sb * sb / nb;
    let se = (va + vb).sqrt();
    if se == 0.0 {
        // degenerate zero-variance samples: decide by the means alone
        return match ma.partial_cmp(&mb) {
            Some(std::cmp::Ordering::Less) => (f64::NEG_INFINITY, 0.0),
            Some(std::cmp::Ordering::Greater) => (f64::INFINITY, 1.0),
            _ => (0.0, 0.5),
        };
    }
    let t = (ma - mb) / se;
    // Welch–Satterthwaite degrees of freedom
    let df = (va + vb).powi(2)
        / (va * va / (na - 1.0).max(1.0) + vb * vb / (nb - 1.0).max(1.0));
    // one-sided p = P(T_df <= t)
    let p = student_t_cdf(t, df);
    (t, p)
}

/// Student-t CDF via the regularized incomplete beta function.
pub fn student_t_cdf(t: f64, df: f64) -> f64 {
    if !t.is_finite() {
        return if t > 0.0 { 1.0 } else { 0.0 };
    }
    let x = df / (df + t * t);
    let ib = 0.5 * reg_inc_beta(0.5 * df, 0.5, x);
    if t >= 0.0 {
        1.0 - ib
    } else {
        ib
    }
}

/// Regularized incomplete beta I_x(a, b) via Lentz continued fraction.
pub fn reg_inc_beta(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_beta = ln_gamma(a) + ln_gamma(b) - ln_gamma(a + b);
    // front = x^a (1-x)^b / B(a,b) — symmetric under (a,b,x)<->(b,a,1-x)
    let front = (a * x.ln() + b * (1.0 - x).ln() - ln_beta).exp();
    // Pick whichever continued fraction converges fast (no recursion:
    // the symmetric branch is computed directly to avoid the x == 0.5
    // fixed point).
    if x <= (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(a, b, x) / a
    } else {
        1.0 - front * beta_cf(b, a, 1.0 - x) / b
    }
}

fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 1e-14;
    const TINY: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < TINY {
        d = TINY;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // even step
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        h *= d * c;
        // odd step
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Lanczos log-gamma.
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        return (std::f64::consts::PI / (std::f64::consts::PI * x).sin()).ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = G[0];
    for (i, &g) in G.iter().enumerate().skip(1) {
        acc += g / (x + i as f64);
    }
    let t = x + 7.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coverage_and_size() {
        let pm = vec![vec![0.9, 0.05], vec![0.2, 0.8], vec![0.04, 0.9]];
        let truth = vec![0, 1, 0];
        assert!((coverage(&pm, &truth, 0.05) - 2.0 / 3.0).abs() < 1e-12);
        assert!((avg_set_size(&pm, 0.1) - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn fuzziness_examples() {
        assert!((fuzziness(&[1.0, 0.2, 0.1]) - 0.3).abs() < 1e-12);
        assert_eq!(fuzziness(&[0.5]), 0.0);
    }

    #[test]
    fn ln_gamma_known_values() {
        // Gamma(5) = 24
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        // Gamma(0.5) = sqrt(pi)
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn t_cdf_reference_points() {
        // symmetric
        assert!((student_t_cdf(0.0, 7.0) - 0.5).abs() < 1e-12);
        // t=1.96, df=large -> ~0.975
        assert!((student_t_cdf(1.96, 1e6) - 0.975).abs() < 1e-3);
        // t distribution df=1 (Cauchy): CDF(1) = 0.75
        assert!((student_t_cdf(1.0, 1.0) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn welch_detects_shift() {
        // a clearly below b
        let a: Vec<f64> = (0..200).map(|i| (i % 10) as f64 * 0.01).collect();
        let b: Vec<f64> = (0..200).map(|i| 1.0 + (i % 10) as f64 * 0.01).collect();
        let (t, p) = welch_one_sided(&a, &b);
        assert!(t < -10.0);
        assert!(p < 1e-6, "p = {p}");
        // and the reverse is not significant
        let (_, p_rev) = welch_one_sided(&b, &a);
        assert!(p_rev > 0.99);
    }

    #[test]
    fn welch_null_is_moderate() {
        let a: Vec<f64> = (0..100).map(|i| (i as f64 * 0.37).sin()).collect();
        let (_, p) = welch_one_sided(&a, &a);
        assert!((p - 0.5).abs() < 1e-9);
    }
}
