//! Nonconformity-measure traits.

use crate::data::{Dataset, Label};

/// The n+1 nonconformity scores full CP needs for one (test object,
/// candidate label) pair — Algorithm 1's LOO loop output.
///
/// `train[i]` is alpha_i = A((x_i, y_i); {(x, y)} u Z \ {(x_i, y_i)})
/// and `test` is alpha = A((x, y); Z).
#[derive(Clone, Debug)]
pub struct Scores {
    pub train: Vec<f64>,
    pub test: f64,
}

/// A nonconformity measure usable by the full CP classifier.
///
/// Implementations come in two flavours with identical outputs:
///
/// * **standard** — `fit` stores the training set; `scores` reruns the
///   measure from scratch for every LOO bag (the paper's baseline
///   complexity, Table 1 "Standard");
/// * **optimized** — `fit` does the paper's incremental&decremental
///   precomputation (provisional scores, k-best structures, model +
///   auxiliary matrix, ...); `scores` applies O(1)/O(q^2) updates
///   (Table 1 "Optimized").
///
/// The exactness contract — optimized `scores` == standard `scores` up to
/// float round-off — is enforced by `rust/tests/exactness.rs` and the
/// proptest suite.
///
/// `Send + Sync` so deployments can sit behind the coordinator's RwLock
/// and be scored from a worker pool (`scores` takes `&self`).
pub trait CpMeasure: Send + Sync {
    /// Human-readable measure name (used by the CLI, benches, reports).
    fn name(&self) -> String;

    /// Train/precompute on the training bag.
    fn fit(&mut self, ds: &Dataset);

    /// Nonconformity scores for candidate-labelled test example (x, y).
    fn scores(&self, x: &[f64], y: Label) -> Scores;

    /// Number of training examples currently fitted.
    fn n(&self) -> usize;

    /// Labels of the fitted training set.
    fn n_labels(&self) -> usize;

    /// Incrementally learn one example (online setting, §9). Returns
    /// false when the measure does not support online updates (standard
    /// variants refit instead).
    fn learn(&mut self, _x: &[f64], _y: Label) -> bool {
        false
    }

    /// Decrementally unlearn the example at training index `idx`.
    fn unlearn(&mut self, _idx: usize) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Dummy {
        n: usize,
    }
    impl CpMeasure for Dummy {
        fn name(&self) -> String {
            "dummy".into()
        }
        fn fit(&mut self, ds: &Dataset) {
            self.n = ds.n();
        }
        fn scores(&self, _x: &[f64], _y: Label) -> Scores {
            Scores {
                train: vec![0.0; self.n],
                test: 0.0,
            }
        }
        fn n(&self) -> usize {
            self.n
        }
        fn n_labels(&self) -> usize {
            2
        }
    }

    #[test]
    fn default_online_hooks_decline() {
        let mut d = Dummy { n: 0 };
        assert!(!d.learn(&[0.0], 0));
        assert!(!d.unlearn(0));
    }
}
