//! Nonconformity-measure traits.

use crate::data::{Dataset, Label};

/// The n+1 nonconformity scores full CP needs for one (test object,
/// candidate label) pair — Algorithm 1's LOO loop output.
///
/// `train[i]` is alpha_i = A((x_i, y_i); {(x, y)} u Z \ {(x_i, y_i)})
/// and `test` is alpha = A((x, y); Z).
#[derive(Clone, Debug)]
pub struct Scores {
    pub train: Vec<f64>,
    pub test: f64,
}

/// A nonconformity measure usable by the full CP classifier.
///
/// Implementations come in two flavours with identical outputs:
///
/// * **standard** — `fit` stores the training set; `scores` reruns the
///   measure from scratch for every LOO bag (the paper's baseline
///   complexity, Table 1 "Standard");
/// * **optimized** — `fit` does the paper's incremental&decremental
///   precomputation (provisional scores, k-best structures, model +
///   auxiliary matrix, ...); `scores` applies O(1)/O(q^2) updates
///   (Table 1 "Optimized").
///
/// The exactness contract — optimized `scores` == standard `scores` up to
/// float round-off — is enforced by `rust/tests/exactness.rs` and the
/// proptest suite.
///
/// `Send + Sync` so deployments can sit behind the coordinator's RwLock
/// and be scored from a worker pool (`scores` takes `&self`).
pub trait CpMeasure: Send + Sync {
    /// Human-readable measure name (used by the CLI, benches, reports).
    fn name(&self) -> String;

    /// Train/precompute on the training bag.
    fn fit(&mut self, ds: &Dataset);

    /// Nonconformity scores for candidate-labelled test example (x, y).
    fn scores(&self, x: &[f64], y: Label) -> Scores;

    /// Batched scoring over the cross product `xs × labels`.
    ///
    /// Returns one [`Scores`] per (test object, candidate label) pair,
    /// laid out x-major: the result has `xs.len() * labels.len()`
    /// entries and entry `i * labels.len() + j` scores `(xs[i],
    /// labels[j])`. An empty `xs` or `labels` yields an empty vector.
    ///
    /// **Contract: identical output to per-pair [`scores`]** — for
    /// every pair, `scores_batch(..)[i * labels.len() + j]` must equal
    /// `scores(xs[i], labels[j])` bit for bit. The default
    /// implementation trivially satisfies this by looping over pairs;
    /// specialized implementations (k-NN, KDE, LS-SVM) compute each
    /// test row's distance/kernel row **once** and reuse it across all
    /// candidate labels and across the LOO provisional-score updates,
    /// turning `l` row computations per test object into one — the
    /// batch-serving hot path. The contract is enforced bit-for-bit by
    /// `rust/tests/proptests.rs` and pinned by the golden fixtures in
    /// `rust/tests/golden_pvalues.rs`.
    ///
    /// [`scores`]: CpMeasure::scores
    fn scores_batch(&self, xs: &[&[f64]], labels: &[Label]) -> Vec<Scores> {
        let mut out = Vec::with_capacity(xs.len() * labels.len());
        for x in xs {
            for &y in labels {
                out.push(self.scores(x, y));
            }
        }
        out
    }

    /// Number of training examples currently fitted.
    fn n(&self) -> usize;

    /// Labels of the fitted training set.
    fn n_labels(&self) -> usize;

    /// Incrementally learn one example (online setting, §9). Returns
    /// false when the measure does not support online updates (standard
    /// variants refit instead).
    fn learn(&mut self, _x: &[f64], _y: Label) -> bool {
        false
    }

    /// Decrementally unlearn the example at training index `idx`.
    fn unlearn(&mut self, _idx: usize) -> bool {
        false
    }
}

/// Boxed measures forward every method — including `scores_batch`, so
/// a `Box<dyn CpMeasure>` keeps its concrete type's specialized batch
/// path. Lets [`crate::cp::FullCp`] wrap factory-built measures.
impl<M: CpMeasure + ?Sized> CpMeasure for Box<M> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn fit(&mut self, ds: &Dataset) {
        (**self).fit(ds)
    }

    fn scores(&self, x: &[f64], y: Label) -> Scores {
        (**self).scores(x, y)
    }

    fn scores_batch(&self, xs: &[&[f64]], labels: &[Label]) -> Vec<Scores> {
        (**self).scores_batch(xs, labels)
    }

    fn n(&self) -> usize {
        (**self).n()
    }

    fn n_labels(&self) -> usize {
        (**self).n_labels()
    }

    fn learn(&mut self, x: &[f64], y: Label) -> bool {
        (**self).learn(x, y)
    }

    fn unlearn(&mut self, idx: usize) -> bool {
        (**self).unlearn(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Dummy {
        n: usize,
    }
    impl CpMeasure for Dummy {
        fn name(&self) -> String {
            "dummy".into()
        }
        fn fit(&mut self, ds: &Dataset) {
            self.n = ds.n();
        }
        fn scores(&self, _x: &[f64], _y: Label) -> Scores {
            Scores {
                train: vec![0.0; self.n],
                test: 0.0,
            }
        }
        fn n(&self) -> usize {
            self.n
        }
        fn n_labels(&self) -> usize {
            2
        }
    }

    #[test]
    fn default_online_hooks_decline() {
        let mut d = Dummy { n: 0 };
        assert!(!d.learn(&[0.0], 0));
        assert!(!d.unlearn(0));
    }

    #[test]
    fn default_scores_batch_is_per_pair_cross_product() {
        let d = Dummy { n: 3 };
        let (a, b) = ([0.0, 1.0], [2.0, 3.0]);
        let xs: Vec<&[f64]> = vec![&a, &b];
        let batch = d.scores_batch(&xs, &[0, 1]);
        assert_eq!(batch.len(), 4);
        for (i, &x) in xs.iter().enumerate() {
            for (j, &y) in [0usize, 1].iter().enumerate() {
                let single = d.scores(x, y);
                let got = &batch[i * 2 + j];
                assert_eq!(got.train, single.train);
                assert_eq!(got.test.to_bits(), single.test.to_bits());
            }
        }
    }

    #[test]
    fn default_scores_batch_empty_inputs() {
        let d = Dummy { n: 2 };
        let x = [0.0];
        let xs: Vec<&[f64]> = vec![&x];
        assert!(d.scores_batch(&[], &[0, 1]).is_empty());
        assert!(d.scores_batch(&xs, &[]).is_empty());
    }
}
