//! Cross-conformal prediction (Vovk 2015) and Aggregated CP (Carlsson
//! et al. 2014) — the remaining rows of the paper's App. A complexity
//! table. Both sit between ICP (fastest, weakest) and full CP (the
//! paper's optimized target): K ICP-like folds whose p-value evidence
//! is pooled.
//!
//! * Cross-CP: K-fold split; fold k's measure is trained on the other
//!   K-1 folds and scores fold k as calibration; the p-value pools the
//!   rank counts across all folds.
//! * Aggregated CP: K independent random proper/calibration splits;
//!   the per-split ICP p-values are averaged.
//!
//! Complexities (App. A): train O((T_A((K-1)n/K) + P_A(n/K))K); predict
//! O((P_A(1) + n/K)K l m).

use crate::cp::icp::IcpMeasure;
use crate::data::{Dataset, Label, Rng};

/// Cross-conformal predictor over a measure factory (one fresh measure
/// per fold).
pub struct CrossCp<M: IcpMeasure> {
    folds: Vec<FoldState<M>>,
    n_labels: usize,
}

struct FoldState<M> {
    measure: M,
    /// calibration scores of this fold's held-out examples, sorted
    calib: Vec<f64>,
}

impl<M: IcpMeasure> CrossCp<M> {
    /// Train with `k_folds` folds; `make_measure` builds one fresh
    /// measure per fold.
    pub fn train(
        ds: &Dataset,
        k_folds: usize,
        seed: u64,
        mut make_measure: impl FnMut() -> M,
    ) -> Self {
        assert!(k_folds >= 2 && k_folds <= ds.n());
        let mut idx: Vec<usize> = (0..ds.n()).collect();
        let mut rng = Rng::seed_from(seed);
        rng.shuffle(&mut idx);
        let mut folds = Vec::with_capacity(k_folds);
        for k in 0..k_folds {
            let held: Vec<usize> = idx
                .iter()
                .copied()
                .skip(k)
                .step_by(k_folds)
                .collect();
            let rest: Vec<usize> = idx
                .iter()
                .copied()
                .filter(|i| !held.contains(i))
                .collect();
            let mut measure = make_measure();
            measure.fit(&ds.subset(&rest));
            let mut calib: Vec<f64> = held
                .iter()
                .map(|&i| measure.score(ds.row(i), ds.y[i]))
                .collect();
            calib.sort_unstable_by(|a, b| a.total_cmp(b));
            folds.push(FoldState { measure, calib });
        }
        CrossCp {
            folds,
            n_labels: ds.n_labels,
        }
    }

    /// Cross-conformal p-value: pooled rank count across folds,
    /// p = (sum_k #{alpha in calib_k : alpha >= alpha_k(x,y)} + 1) / (n + 1).
    pub fn p_value_for(&self, x: &[f64], y: Label) -> f64 {
        let mut ge = 0usize;
        let mut n = 0usize;
        for fold in &self.folds {
            let alpha = fold.measure.score(x, y);
            let idx = fold.calib.partition_point(|&a| a < alpha);
            ge += fold.calib.len() - idx;
            n += fold.calib.len();
        }
        (ge + 1) as f64 / (n + 1) as f64
    }

    pub fn p_values(&self, x: &[f64]) -> Vec<f64> {
        (0..self.n_labels)
            .map(|y| self.p_value_for(x, y))
            .collect()
    }

    pub fn predict_set(&self, x: &[f64], eps: f64) -> Vec<Label> {
        self.p_values(x)
            .iter()
            .enumerate()
            .filter(|(_, &p)| p > eps)
            .map(|(y, _)| y)
            .collect()
    }
}

/// Aggregated conformal predictor: average of K independent ICP
/// p-values over random splits.
pub struct AggregatedCp<M: IcpMeasure> {
    splits: Vec<FoldState<M>>,
    n_labels: usize,
}

impl<M: IcpMeasure> AggregatedCp<M> {
    /// `t` = proper-training size per split.
    pub fn train(
        ds: &Dataset,
        k_splits: usize,
        t: usize,
        seed: u64,
        mut make_measure: impl FnMut() -> M,
    ) -> Self {
        assert!(k_splits >= 1 && t >= 1 && t < ds.n());
        let mut rng = Rng::seed_from(seed);
        let mut splits = Vec::with_capacity(k_splits);
        for _ in 0..k_splits {
            let mut idx: Vec<usize> = (0..ds.n()).collect();
            rng.shuffle(&mut idx);
            let mut measure = make_measure();
            measure.fit(&ds.subset(&idx[..t]));
            let mut calib: Vec<f64> = idx[t..]
                .iter()
                .map(|&i| measure.score(ds.row(i), ds.y[i]))
                .collect();
            calib.sort_unstable_by(|a, b| a.total_cmp(b));
            splits.push(FoldState { measure, calib });
        }
        AggregatedCp {
            splits,
            n_labels: ds.n_labels,
        }
    }

    /// Mean of the per-split ICP p-values.
    pub fn p_value_for(&self, x: &[f64], y: Label) -> f64 {
        let mut sum = 0.0;
        for s in &self.splits {
            let alpha = s.measure.score(x, y);
            let idx = s.calib.partition_point(|&a| a < alpha);
            let ge = s.calib.len() - idx;
            sum += (ge + 1) as f64 / (s.calib.len() + 1) as f64;
        }
        sum / self.splits.len() as f64
    }

    pub fn p_values(&self, x: &[f64]) -> Vec<f64> {
        (0..self.n_labels)
            .map(|y| self.p_value_for(x, y))
            .collect()
    }

    pub fn predict_set(&self, x: &[f64], eps: f64) -> Vec<Label> {
        self.p_values(x)
            .iter()
            .enumerate()
            .filter(|(_, &p)| p > eps)
            .map(|(y, _)| y)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::metrics::coverage;
    use crate::data::{make_classification, ClassificationSpec};
    use crate::measures::IcpKnn;

    fn data(n: usize, seed: u64) -> Dataset {
        make_classification(
            &ClassificationSpec {
                n_samples: n,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn cross_cp_valid_coverage() {
        let all = data(300, 1);
        let mut rng = Rng::seed_from(2);
        let (train, test) = all.split(220, &mut rng);
        let cp = CrossCp::train(&train, 5, 3, || IcpKnn::new(5, true));
        let pm: Vec<Vec<f64>> =
            (0..test.n()).map(|i| cp.p_values(test.row(i))).collect();
        for eps in [0.1, 0.2] {
            let cov = coverage(&pm, &test.y, eps);
            assert!(cov >= 1.0 - eps - 0.13, "eps={eps}: {cov}");
        }
    }

    #[test]
    fn aggregated_cp_valid_coverage() {
        let all = data(300, 4);
        let mut rng = Rng::seed_from(5);
        let (train, test) = all.split(220, &mut rng);
        let cp = AggregatedCp::train(&train, 4, 110, 6, || IcpKnn::new(5, true));
        let pm: Vec<Vec<f64>> =
            (0..test.n()).map(|i| cp.p_values(test.row(i))).collect();
        // aggregated CP's guarantee is approximate; allow extra slack
        let cov = coverage(&pm, &test.y, 0.1);
        assert!(cov >= 0.75, "coverage {cov}");
    }

    #[test]
    fn folds_partition_data() {
        let train = data(50, 7);
        let cp = CrossCp::train(&train, 5, 8, || IcpKnn::new(3, true));
        let total: usize = cp.folds.iter().map(|f| f.calib.len()).sum();
        assert_eq!(total, 50, "every example is calibration exactly once");
    }

    #[test]
    fn pvalues_discriminate() {
        let train = data(120, 9);
        let cp = CrossCp::train(&train, 4, 10, || IcpKnn::new(5, true));
        // training points should get higher p for their own label
        let (mut own, mut other) = (0.0, 0.0);
        for i in 0..20 {
            own += cp.p_value_for(train.row(i), train.y[i]);
            other += cp.p_value_for(train.row(i), 1 - train.y[i]);
        }
        assert!(own > other, "{own} vs {other}");
    }
}
