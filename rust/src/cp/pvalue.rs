//! Conformal p-values.

use crate::cp::measure::{CpMeasure, Scores};
use crate::data::Label;

/// Plain conformal p-value (Algorithm 1, line 5):
/// p = (#{i : alpha_i >= alpha} + 1) / (n + 1).
///
/// The "+1" in the numerator counts the test example itself
/// (alpha >= alpha trivially), making p uniform over
/// {1/(n+1), ..., 1} under exchangeability.
pub fn p_value(s: &Scores) -> f64 {
    let ge = s.train.iter().filter(|&&a| a >= s.test).count();
    (ge + 1) as f64 / (s.train.len() + 1) as f64
}

/// One row of per-label p-values per test object, from ONE
/// [`CpMeasure::scores_batch`] pass over `xs × (0..n_labels)` — the
/// shared core of `FullCp::p_values_batch` and the coordinator's
/// `Deployment::p_values_batch`. Row i corresponds to `xs[i]`; equal
/// to per-pair scoring bit for bit (the measure's batch contract).
pub fn p_value_rows<M: CpMeasure + ?Sized>(
    measure: &M,
    xs: &[&[f64]],
    n_labels: usize,
) -> Vec<Vec<f64>> {
    if n_labels == 0 {
        return xs.iter().map(|_| Vec::new()).collect();
    }
    let labels: Vec<Label> = (0..n_labels).collect();
    // Tracing spans time the two stages; they read the clock and the
    // finished score buffers only — the float path is untouched.
    let dims = [xs.len() as u64, n_labels as u64, 0, 0];
    let scores = {
        let _span =
            crate::obs::trace::span_args(crate::obs::Stage::MeasureScores, dims);
        measure.scores_batch(xs, &labels)
    };
    let _span = crate::obs::trace::span_args(crate::obs::Stage::PValueAgg, dims);
    scores
        .chunks(n_labels)
        .map(|row| row.iter().map(p_value).collect())
        .collect()
}

/// Smoothed conformal p-value:
/// p = (#{alpha_i > alpha} + tau * (#{alpha_i == alpha} + 1)) / (n + 1)
/// with tau ~ U[0,1]. Exactly uniform under exchangeability — required
/// by the exchangeability martingales of the online IID test (§9).
pub fn smoothed_p_value(s: &Scores, tau: f64) -> f64 {
    let mut gt = 0usize;
    let mut eq = 0usize;
    for &a in &s.train {
        if a > s.test {
            gt += 1;
        } else if a == s.test {
            eq += 1;
        }
    }
    (gt as f64 + tau * (eq + 1) as f64) / (s.train.len() + 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scores(train: Vec<f64>, test: f64) -> Scores {
        Scores { train, test }
    }

    #[test]
    fn p_value_counts_ge() {
        // train scores 1..4, test 2.5 -> two >= -> (2+1)/5
        let s = scores(vec![1.0, 2.0, 3.0, 4.0], 2.5);
        assert_eq!(p_value(&s), 3.0 / 5.0);
    }

    #[test]
    fn p_value_extremes() {
        let s = scores(vec![1.0, 2.0, 3.0], 10.0);
        assert_eq!(p_value(&s), 1.0 / 4.0); // most nonconforming
        let s = scores(vec![1.0, 2.0, 3.0], 0.0);
        assert_eq!(p_value(&s), 1.0); // most conforming
    }

    #[test]
    fn p_value_handles_infinities() {
        let s = scores(vec![f64::INFINITY, 1.0], f64::INFINITY);
        // inf >= inf counts
        assert_eq!(p_value(&s), 2.0 / 3.0);
    }

    #[test]
    fn smoothed_brackets_plain() {
        let s = scores(vec![1.0, 2.0, 2.0, 3.0], 2.0);
        let lo = smoothed_p_value(&s, 0.0);
        let hi = smoothed_p_value(&s, 1.0);
        let plain = p_value(&s);
        assert!(lo <= plain && plain <= hi, "{lo} {plain} {hi}");
        assert_eq!(hi, plain); // tau=1 recovers the plain p-value
    }

    #[test]
    fn smoothed_is_linear_in_tau() {
        let s = scores(vec![1.0, 2.0, 2.0, 3.0], 2.0);
        let a = smoothed_p_value(&s, 0.25);
        let b = smoothed_p_value(&s, 0.75);
        let mid = smoothed_p_value(&s, 0.5);
        assert!((mid - (a + b) / 2.0).abs() < 1e-12);
    }
}
