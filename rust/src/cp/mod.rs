//! Conformal prediction core (paper §2).
//!
//! - [`measure`] — the nonconformity-measure traits. The key design
//!   point (paper §3.1): a measure that can *learn* and *unlearn* one
//!   example efficiently turns full CP's LOO loop from
//!   O(T_A(n) + P_A(1)) per training point into O(1) amortized.
//! - [`pvalue`] — plain and smoothed conformal p-values.
//! - [`classifier`] — the full (transductive) CP classifier, Algorithm 1.
//! - [`icp`] — Inductive CP, Algorithm 2 (the computational baseline).
//! - [`metrics`] — validity/efficiency metrics: coverage, set size,
//!   fuzziness (Vovk et al. 2016), Welch's one-sided t-test (App. G).

pub mod classifier;
pub mod crosscp;
pub mod icp;
pub mod measure;
pub mod metrics;
pub mod pvalue;

pub use classifier::FullCp;
pub use crosscp::{AggregatedCp, CrossCp};
pub use icp::{Icp, IcpMeasure};
pub use measure::{CpMeasure, Scores};
pub use pvalue::{p_value, smoothed_p_value};
