//! The critical-point machinery of full CP regression (paper §8).
//!
//! Every full-CP regressor here (k-NN, ridge) reduces to nonconformity
//! scores that are absolute values of affine functions of the candidate
//! label:  alpha_i(y~) = |a_i + b_i y~|  and  alpha(y~) = |a + b y~|.
//! The prediction region { y~ : p(y~) > eps } is then computable exactly
//! by sweeping the O(2n) critical points where the comparison
//! alpha_i(y~) >= alpha(y~) flips (Papadopoulos et al. 2011;
//! Nouretdinov et al. 2001), in O(n log n).

/// A closed interval of the real line; endpoints may be +-inf.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interval {
    pub lo: f64,
    pub hi: f64,
}

impl Interval {
    pub fn new(lo: f64, hi: f64) -> Self {
        Interval { lo, hi }
    }

    pub fn contains(&self, y: f64) -> bool {
        self.lo <= y && y <= self.hi
    }

    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }
}

/// A prediction region: a finite union of closed intervals (sorted,
/// disjoint). Boundary resolution is the critical-point grid — the same
/// granularity as the Papadopoulos et al. algorithm.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Region {
    pub intervals: Vec<Interval>,
}

impl Region {
    pub fn contains(&self, y: f64) -> bool {
        self.intervals.iter().any(|iv| iv.contains(y))
    }

    pub fn is_empty(&self) -> bool {
        self.intervals.is_empty()
    }

    /// Convex hull [min lo, max hi] — what's usually reported as "the"
    /// conformal interval.
    pub fn hull(&self) -> Option<Interval> {
        if self.intervals.is_empty() {
            return None;
        }
        Some(Interval::new(
            self.intervals[0].lo,
            self.intervals[self.intervals.len() - 1].hi,
        ))
    }

    /// Total length (inf if any piece is unbounded).
    pub fn total_width(&self) -> f64 {
        // EXACT-ALLOW: EXACT001 diagnostic width in sorted-interval
        // order; regions are compared by endpoints, not by this sum.
        self.intervals.iter().map(Interval::width).sum()
    }
}

/// The set S_i = { y~ : |a_i + b_i y~| >= |a + b y~| } as a union of at
/// most two closed intervals (possibly empty / unbounded / all of R).
///
/// Derivation: |u| >= |v|  <=>  (u - v)(u + v) >= 0 with
/// u = a_i + b_i y~, v = a + b y~ — a product of two affine functions
/// f1 = (a_i - a) + (b_i - b) y~ and f2 = (a_i + a) + (b_i + b) y~.
pub fn ge_set(a_i: f64, b_i: f64, a: f64, b: f64) -> Vec<Interval> {
    let (c1, s1) = (a_i - a, b_i - b);
    let (c2, s2) = (a_i + a, b_i + b);
    let all = vec![Interval::new(f64::NEG_INFINITY, f64::INFINITY)];
    match (s1 == 0.0, s2 == 0.0) {
        (true, true) => {
            if c1 * c2 >= 0.0 {
                all
            } else {
                vec![]
            }
        }
        (true, false) => half_line_product(c1, c2, s2),
        (false, true) => half_line_product(c2, c1, s1),
        (false, false) => {
            let r1 = -c1 / s1;
            let r2 = -c2 / s2;
            let (lo, hi) = if r1 <= r2 { (r1, r2) } else { (r2, r1) };
            if s1 * s2 < 0.0 {
                // downward parabola: >= 0 between the roots
                vec![Interval::new(lo, hi)]
            } else {
                // upward parabola: >= 0 outside the roots
                vec![
                    Interval::new(f64::NEG_INFINITY, lo),
                    Interval::new(hi, f64::INFINITY),
                ]
            }
        }
    }
}

/// Product (constant c) * (affine c2 + s2 y) >= 0 with s2 != 0.
fn half_line_product(c: f64, c2: f64, s2: f64) -> Vec<Interval> {
    let root = -c2 / s2;
    if c == 0.0 {
        // product identically 0 -> everywhere
        return vec![Interval::new(f64::NEG_INFINITY, f64::INFINITY)];
    }
    // need sign(affine) == sign(c) (or affine == 0)
    if (c > 0.0) == (s2 > 0.0) {
        vec![Interval::new(root, f64::INFINITY)]
    } else {
        vec![Interval::new(f64::NEG_INFINITY, root)]
    }
}

/// Exact conformal prediction region from affine score coefficients.
///
/// `coefs[i] = (a_i, b_i)` for the n training examples; `(a, b)` are the
/// test example's coefficients; the region is
/// { y~ : (#{i : alpha_i(y~) >= alpha(y~)} + 1) / (n + 1) > eps }.
pub fn conformal_region(coefs: &[(f64, f64)], a: f64, b: f64, eps: f64) -> Region {
    let n = coefs.len();
    // qualify at count >= need, where count = #{i in S_i}
    // (count + 1)/(n + 1) > eps  <=>  count > eps (n+1) - 1
    let need = (eps * (n + 1) as f64 - 1.0).floor() as i64 + 1;
    let need = need.max(0) as usize;

    // Gather intervals; track how many are (-inf, ...] (active at -inf).
    #[derive(Clone, Copy)]
    struct Ev {
        t: f64,
        start: bool,
    }
    let mut events: Vec<Ev> = Vec::with_capacity(2 * n);
    let mut active_at_neg_inf = 0usize;
    for &(a_i, b_i) in coefs {
        for iv in ge_set(a_i, b_i, a, b) {
            if iv.lo == f64::NEG_INFINITY {
                active_at_neg_inf += 1;
            } else {
                events.push(Ev {
                    t: iv.lo,
                    start: true,
                });
            }
            if iv.hi != f64::INFINITY {
                events.push(Ev {
                    t: iv.hi,
                    start: false,
                });
            }
        }
    }
    events.sort_by(|x, y| x.t.total_cmp(&y.t));

    let mut out: Vec<Interval> = Vec::new();
    let mut cur_start: Option<f64> = None;
    let mut count = active_at_neg_inf;
    if count >= need {
        cur_start = Some(f64::NEG_INFINITY);
    }

    let mut i = 0usize;
    while i < events.len() {
        let t = events[i].t;
        let seg_count = count; // count on the open segment before t
        let mut starts = 0usize;
        let mut ends = 0usize;
        while i < events.len() && events[i].t == t {
            if events[i].start {
                starts += 1;
            } else {
                ends += 1;
            }
            i += 1;
        }
        let at_t = seg_count + starts; // closed intervals: ends still active AT t
        let after = at_t - ends;

        let q_at = at_t >= need;
        let q_after = after >= need;
        match (cur_start.is_some(), q_at, q_after) {
            (false, true, true) => cur_start = Some(t),
            (false, true, false) => out.push(Interval::new(t, t)),
            (true, true, false) | (true, false, false) => {
                // region closes at t (if q_at) or just before (boundary
                // resolution is the critical point itself)
                out.push(Interval::new(cur_start.take().unwrap(), t));
            }
            _ => {}
        }
        count = after;
    }
    if let Some(s) = cur_start {
        out.push(Interval::new(s, f64::INFINITY));
    }
    // merge touching intervals
    let mut merged: Vec<Interval> = Vec::with_capacity(out.len());
    for iv in out {
        match merged.last_mut() {
            Some(last) if iv.lo <= last.hi => last.hi = last.hi.max(iv.hi),
            _ => merged.push(iv),
        }
    }
    Region { intervals: merged }
}

/// Direct O(n) p-value at a single candidate label — the oracle the
/// sweep is tested against (and the validity-test workhorse).
pub fn p_value_at(coefs: &[(f64, f64)], a: f64, b: f64, y: f64) -> f64 {
    let alpha = (a + b * y).abs();
    let ge = coefs
        .iter()
        .filter(|(ai, bi)| (ai + bi * y).abs() >= alpha)
        .count();
    (ge + 1) as f64 / (coefs.len() + 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn ge_set_bounded_case() {
        // b_i = 0, b = 1: |a_i| >= |a + y| -> y in [-a - |a_i|, -a + |a_i|]
        let s = ge_set(2.0, 0.0, 1.0, 1.0);
        assert_eq!(s, vec![Interval::new(-3.0, 1.0)]);
    }

    #[test]
    fn ge_set_outside_case() {
        // |2y| >= |1 + y|: f1 = -1 + y (root 1), f2 = 1 + 3y (root -1/3);
        // slopes (1, 3) same sign -> outside the roots
        let s = ge_set(0.0, 2.0, 1.0, 1.0);
        assert_eq!(s.len(), 2);
        assert!((s[0].hi - (-1.0 / 3.0)).abs() < 1e-12);
        assert!((s[1].lo - 1.0).abs() < 1e-12);
    }

    /// Brute-force check of ge_set against direct evaluation.
    #[test]
    fn ge_set_matches_pointwise() {
        let mut rng = Rng::seed_from(1);
        for _ in 0..500 {
            let a_i = rng.normal() * 2.0;
            let b_i = match rng.below(4) {
                0 => 0.0,
                1 => -1.0,
                2 => -0.25,
                _ => rng.normal(),
            };
            let a = rng.normal();
            let b = if rng.below(5) == 0 { 0.0 } else { 1.0 };
            let set = ge_set(a_i, b_i, a, b);
            for step in -40..=40 {
                let y = step as f64 * 0.25;
                let want = (a_i + b_i * y).abs() >= (a + b * y).abs();
                let got = set.iter().any(|iv| iv.contains(y));
                // boundary fuzz: skip near-equality points
                let gap = ((a_i + b_i * y).abs() - (a + b * y).abs()).abs();
                if gap > 1e-9 {
                    assert_eq!(
                        got, want,
                        "a_i={a_i} b_i={b_i} a={a} b={b} y={y} set={set:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn region_matches_pointwise_pvalue() {
        let mut rng = Rng::seed_from(2);
        for trial in 0..100 {
            let n = 5 + rng.below(30);
            let coefs: Vec<(f64, f64)> = (0..n)
                .map(|_| {
                    let a_i = rng.normal() * 3.0;
                    let b_i = if rng.below(2) == 0 { 0.0 } else { -0.2 };
                    (a_i, b_i)
                })
                .collect();
            let a = rng.normal();
            let eps = [0.05, 0.1, 0.2, 0.5][rng.below(4)];
            let region = conformal_region(&coefs, a, 1.0, eps);
            for step in -60..=60 {
                let y = step as f64 * 0.2;
                let p = p_value_at(&coefs, a, 1.0, y);
                let want = p > eps;
                let got = region.contains(y);
                // skip points within float fuzz of a critical point
                let near_crit = coefs.iter().any(|&(ai, bi)| {
                    ((ai + bi * y).abs() - (a + y).abs()).abs() < 1e-9
                });
                if !near_crit {
                    assert_eq!(
                        got, want,
                        "trial={trial} y={y} p={p} eps={eps} region={region:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn region_hull_and_width() {
        let r = Region {
            intervals: vec![Interval::new(0.0, 1.0), Interval::new(2.0, 3.0)],
        };
        assert_eq!(r.hull(), Some(Interval::new(0.0, 3.0)));
        assert_eq!(r.total_width(), 2.0);
        assert!(r.contains(2.5));
        assert!(!r.contains(1.5));
    }

    #[test]
    fn eps_one_gives_empty_eps_zero_gives_all() {
        let coefs = vec![(1.0, 0.0); 9];
        let r_all = conformal_region(&coefs, 0.0, 1.0, 0.0);
        assert!(r_all.contains(0.0) && r_all.contains(100.0));
        let r_none = conformal_region(&coefs, 0.0, 1.0, 0.9999);
        assert!(r_none.is_empty() || r_none.total_width() < 1e30);
    }
}
