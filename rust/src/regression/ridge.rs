//! Ridge-regression full CP (the Ridge Regression Confidence Machine of
//! Nouretdinov et al. 2001), optimized with incremental learning — the
//! §8 "Discussion" extension the paper leaves to future work: applying
//! the LS-SVM-style incremental update (Sherman–Morrison on the p x p
//! ridge inverse) removes the per-test-point refactorization.
//!
//! With augmented design X~ = [X; x] and targets Y~(y~) = (Y, y~), the
//! residual vector is affine in y~:
//!   e(y~) = (I - H) (Y, 0) + (I - H) e_{n+1} y~,  H = X~ M X~^T,
//!   M = (X~^T X~ + rho I_p)^-1,
//! so alpha_i(y~) = |A_i + B_i y~| feeds the same critical-point sweep
//! as k-NN regression ([`crate::regression::region`]).
//!
//! Cost per test point: O(p^2) Sherman–Morrison update of M (vs O(p^3)
//! refactorization for the unoptimized variant) + O(n p) coefficient
//! assembly + O(n log n) sweep.
//!
//! # Decremental learning: the sufficient-statistic journal
//!
//! The training-set state is fully described by the sufficient
//! statistics `G = X^T X` (upper triangle) and `X^T Y`, which a fresh
//! fit accumulates as per-entry *sequential sums over rows in canonical
//! (insertion) order* — one [`linalg::gram_accum_row`] /
//! [`linalg::tmatvec_accum_row`] rank-1 term per example. Sequential
//! floating-point summation is resumable: the value of each entry
//! depends only on the *sequence* of addends, so a prefix of the
//! accumulation plus a replay of the remaining rows in order reproduces
//! the one-shot fit bit for bit. The journal therefore keeps prefix
//! checkpoints of `(G, X^T Y)` every [`CKPT_EVERY`] rows; unlearning
//! row `idx` restores the deepest checkpoint at or before `idx`,
//! removes the row, and replays the surviving suffix — identical adds,
//! identical bits to a from-scratch refit on the reduced set. No
//! Sherman–Morrison *downdate* is used anywhere: a downdate is
//! algebraically exact but not floating-point exact, and the contract
//! here (EXACTNESS.md "Decremental paths") is bit-identity.
//!
//! Cost: unlearning row `idx` replays at most `CKPT_EVERY - 1` rows of
//! prefix slack plus the `n - idx - 1` rows behind it, then one O(p^3)
//! refactorization — O(p^3) for the paper's online pattern (removing
//! recent examples) vs O(n p^2 + p^3) for a refit. Checkpoint memory is
//! O(n p^2 / CKPT_EVERY).

use crate::data::RegressionDataset;
use crate::linalg::{self, dot, Mat};
use crate::regression::region::{conformal_region, p_value_at, Region};
use crate::regression::{Coefficients, CpRegressor};

/// Journal checkpoint cadence (rows between prefix snapshots).
const CKPT_EVERY: usize = 64;

/// A prefix checkpoint: the sufficient statistics after accumulating
/// the first `rows` training examples in canonical order.
struct Ckpt {
    rows: usize,
    /// upper triangle only (mirrored at finalization, like `Mat::gram`)
    gram: Mat,
    xty: Vec<f64>,
}

/// Full CP ridge regressor.
pub struct RidgeCp {
    pub rho: f64,
    ds: Option<RegressionDataset>,
    /// (X^T X + rho I)^-1 over the training set (updated per test point
    /// via Sherman–Morrison, never refactorized)
    m0: Option<Mat>,
    /// X^T Y over the training set — also the journal's running
    /// accumulator (sequential over rows in canonical order)
    xty: Vec<f64>,
    /// running upper-triangle accumulation of X^T X (no ridge term),
    /// replaying `Mat::gram`'s add sequence row by row
    gram_acc: Mat,
    /// prefix checkpoints of `(gram_acc, xty)`, ascending in `rows`
    ckpts: Vec<Ckpt>,
}

impl RidgeCp {
    pub fn new(rho: f64) -> Self {
        RidgeCp {
            rho,
            ds: None,
            m0: None,
            xty: Vec::new(),
            gram_acc: Mat::zeros(0, 0),
            ckpts: Vec::new(),
        }
    }

    /// O(n p^2 + p^3) one-off training (builds the journal as it goes).
    pub fn fit(&mut self, ds: &RegressionDataset) {
        let p = ds.p;
        self.ds = Some(ds.clone());
        self.gram_acc = Mat::zeros(p, p);
        self.xty = vec![0.0; p];
        self.ckpts = Vec::new();
        self.accum_rows(0);
        self.finalize();
    }

    /// Accumulate training rows `from..n` into the journal state in
    /// canonical order, snapshotting a checkpoint whenever the prefix
    /// length crosses a [`CKPT_EVERY`] boundary. Callers guarantee the
    /// current `(gram_acc, xty)` is exactly the accumulation of rows
    /// `0..from` and that no checkpoint deeper than `from` is stored.
    fn accum_rows(&mut self, from: usize) {
        let ds = self.ds.take().expect("fit first");
        for i in from..ds.n() {
            let due = i > 0 && i % CKPT_EVERY == 0;
            if due && self.ckpts.last().is_none_or(|c| c.rows < i) {
                self.ckpts.push(Ckpt {
                    rows: i,
                    gram: self.gram_acc.clone(),
                    xty: self.xty.clone(),
                });
            }
            linalg::gram_accum_row(&mut self.gram_acc, ds.row(i));
            linalg::tmatvec_accum_row(&mut self.xty, ds.y[i], ds.row(i));
        }
        self.ds = Some(ds);
    }

    /// Refresh the factorization from the journal accumulators exactly
    /// like the one-shot path: mirror the upper triangle (the tail of
    /// `Mat::gram`), add the ridge, invert.
    fn finalize(&mut self) {
        let mut g = self.gram_acc.clone();
        g.mirror_upper_to_lower();
        g.add_diag(self.rho);
        self.m0 = Some(linalg::spd_inverse(&g).expect("ridge Gram SPD"));
    }

    /// Incrementally learn one example: one rank-1 journal append +
    /// O(p^3) refactorization — bit-identical to refitting on the grown
    /// set because the append extends the same sequential sums.
    pub fn learn(&mut self, x: &[f64], y: f64) -> bool {
        let Some(ds) = self.ds.as_mut() else {
            return false;
        };
        if x.len() != ds.p {
            return false;
        }
        let i = ds.n();
        ds.push(x, y);
        self.accum_rows(i);
        self.finalize();
        true
    }

    /// Decrementally unlearn the training row at `idx`: restore the
    /// deepest journal checkpoint covering only rows before `idx`,
    /// drop the row, replay the surviving suffix in canonical order,
    /// refactorize. Bit-identical to a fresh fit on the reduced set
    /// (module docs); returns false if `idx` is out of range.
    pub fn unlearn(&mut self, idx: usize) -> bool {
        let Some(ds) = self.ds.as_mut() else {
            return false;
        };
        if idx >= ds.n() {
            return false;
        }
        let p = ds.p;
        ds.remove(idx);
        // a checkpoint of the first `rows` examples survives iff it
        // contains no removed row, i.e. rows <= idx
        while self.ckpts.last().is_some_and(|c| c.rows > idx) {
            self.ckpts.pop();
        }
        let from = match self.ckpts.last() {
            Some(c) => {
                self.gram_acc = c.gram.clone();
                self.xty = c.xty.clone();
                c.rows
            }
            None => {
                self.gram_acc = Mat::zeros(p, p);
                self.xty = vec![0.0; p];
                0
            }
        };
        self.accum_rows(from);
        self.finalize();
        true
    }

    pub fn n(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n())
    }

    /// Shared assembly for the single and batched paths, given the
    /// Sherman–Morrison ingredients `m0x = M0 x` and the test-independent
    /// `m0_xty = M0 (X^T Y)`. Because both entry points funnel through
    /// here, batched output is bit-identical to single-object output by
    /// construction.
    fn coefs_from(&self, x: &[f64], m0x: &[f64], m0_xty: &[f64]) -> Coefficients {
        let ds = self.ds.as_ref().expect("fit first");
        let n = ds.n();

        // Sherman–Morrison: M = (G0 + x x^T)^-1 = M0 - M0 x x^T M0 / (1 + x^T M0 x)
        let denom = 1.0 + dot(x, m0x);
        // w_a = M (X^T Y)  [note X~^T (Y,0) = X^T Y]
        // Apply SM without materializing M: M v = M0 v - m0x (m0x . v)/denom
        let mv = |m0v: &[f64], v: &[f64]| -> Vec<f64> {
            let corr = dot(m0x, v) / denom;
            m0v.iter().zip(m0x).map(|(a, b)| a - b * corr).collect()
        };
        let w_a = mv(m0_xty, &self.xty);
        // M0 x is exactly m0x, so w_b needs no extra matvec
        let w_b = mv(m0x, x);

        // A_i = y_i - x_i . w_a ; B_i = -x_i . w_b (i <= n)
        let coefs: Vec<(f64, f64)> = (0..n)
            .map(|i| {
                let xi = ds.row(i);
                (ds.y[i] - dot(xi, &w_a), -dot(xi, &w_b))
            })
            .collect();
        // test row: A = -x . w_a ; B = 1 - x . w_b
        let a = -dot(x, &w_a);
        let b = 1.0 - dot(x, &w_b);
        (coefs, a, b)
    }

    /// Affine residual coefficients for test object `x`:
    /// returns (per-training (A_i, B_i), A_test, B_test).
    pub fn coefficients(&self, x: &[f64]) -> Coefficients {
        let m0 = self.m0.as_ref().expect("fit first");
        let m0x = m0.matvec(x);
        let m0_xty = m0.matvec(&self.xty);
        self.coefs_from(x, &m0x, &m0_xty)
    }

    /// Batched coefficients: `M0 (X^T Y)` does not depend on the test
    /// object, so it is computed once per batch instead of once per
    /// object. Bit-identical to per-object
    /// [`coefficients`](Self::coefficients) because `Mat::matvec` is
    /// deterministic and the assembly is shared.
    pub fn coefficients_batch(&self, xs: &[&[f64]]) -> Vec<Coefficients> {
        if xs.is_empty() {
            return Vec::new();
        }
        let m0 = self.m0.as_ref().expect("fit first");
        let m0_xty = m0.matvec(&self.xty);
        xs.iter()
            .map(|&x| {
                let m0x = m0.matvec(x);
                self.coefs_from(x, &m0x, &m0_xty)
            })
            .collect()
    }

    pub fn predict_region(&self, x: &[f64], eps: f64) -> Region {
        let (coefs, a, b) = self.coefficients(x);
        conformal_region(&coefs, a, b, eps)
    }

    /// Batched regions at a shared eps; exactly equals mapping
    /// [`predict_region`](Self::predict_region) over `xs`.
    pub fn predict_region_batch(&self, xs: &[&[f64]], eps: f64) -> Vec<Region> {
        self.coefficients_batch(xs)
            .into_iter()
            .map(|(coefs, a, b)| conformal_region(&coefs, a, b, eps))
            .collect()
    }

    pub fn p_value(&self, x: &[f64], y: f64) -> f64 {
        let (coefs, a, b) = self.coefficients(x);
        p_value_at(&coefs, a, b, y)
    }

    /// Batched p-values over paired `(xs[i], ys[i])`; bit-identical to
    /// per-pair [`p_value`](Self::p_value).
    pub fn p_values_batch(&self, xs: &[&[f64]], ys: &[f64]) -> Vec<f64> {
        assert_eq!(xs.len(), ys.len());
        self.coefficients_batch(xs)
            .into_iter()
            .zip(ys)
            .map(|((coefs, a, b), &y)| p_value_at(&coefs, a, b, y))
            .collect()
    }
}

impl CpRegressor for RidgeCp {
    fn name(&self) -> String {
        format!("ridge(rho={})", self.rho)
    }

    fn fit(&mut self, ds: &RegressionDataset) {
        RidgeCp::fit(self, ds)
    }

    fn coefficients(&self, x: &[f64]) -> Coefficients {
        RidgeCp::coefficients(self, x)
    }

    fn coefficients_batch(&self, xs: &[&[f64]]) -> Vec<Coefficients> {
        RidgeCp::coefficients_batch(self, xs)
    }

    fn n(&self) -> usize {
        RidgeCp::n(self)
    }

    fn learn(&mut self, x: &[f64], y: f64) -> bool {
        RidgeCp::learn(self, x, y)
    }

    fn unlearn(&mut self, idx: usize) -> bool {
        RidgeCp::unlearn(self, idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_regression, RegressionSpec, Rng};

    fn ds(n: usize, seed: u64) -> RegressionDataset {
        make_regression(
            &RegressionSpec {
                n_samples: n,
                n_features: 5,
                n_informative: 5,
                noise: 2.0,
            },
            seed,
        )
    }

    /// Oracle: recompute the residual coefficients by explicitly
    /// building the (n+1)x(n+1) hat matrix.
    fn oracle_coefs(
        ds: &RegressionDataset,
        x: &[f64],
        rho: f64,
    ) -> (Vec<(f64, f64)>, f64, f64) {
        let n = ds.n();
        let p = ds.p;
        let mut xa = Mat::zeros(n + 1, p);
        xa.data[..n * p].copy_from_slice(&ds.x);
        xa.row_mut(n).copy_from_slice(x);
        let mut g = xa.gram();
        g.add_diag(rho);
        let minv = linalg::spd_inverse(&g).unwrap();
        // A = (Y,0) - Xa M Xa^T (Y,0) ; B = e_n+1 - Xa M Xa^T e_n+1
        let mut y0 = ds.y.clone();
        y0.push(0.0);
        let w_a = minv.matvec(&xa.tmatvec(&y0));
        let mut e = vec![0.0; n + 1];
        e[n] = 1.0;
        let w_b = minv.matvec(&xa.tmatvec(&e));
        let coefs: Vec<(f64, f64)> = (0..n)
            .map(|i| {
                (
                    y0[i] - dot(xa.row(i), &w_a),
                    e[i] - dot(xa.row(i), &w_b),
                )
            })
            .collect();
        let a = y0[n] - dot(xa.row(n), &w_a);
        let b = e[n] - dot(xa.row(n), &w_b);
        (coefs, a, b)
    }

    #[test]
    fn sherman_morrison_matches_oracle() {
        let d = ds(30, 1);
        let mut r = RidgeCp::new(1.0);
        r.fit(&d);
        let probe = ds(5, 2);
        for i in 0..probe.n() {
            let (got, ga, gb) = r.coefficients(probe.row(i));
            let (want, wa, wb) = oracle_coefs(&d, probe.row(i), 1.0);
            for ((g1, g2), (w1, w2)) in got.iter().zip(&want) {
                assert!((g1 - w1).abs() < 1e-8, "{g1} vs {w1}");
                assert!((g2 - w2).abs() < 1e-8, "{g2} vs {w2}");
            }
            assert!((ga - wa).abs() < 1e-8);
            assert!((gb - wb).abs() < 1e-8);
        }
    }

    #[test]
    fn batch_coefficients_bitwise_identical() {
        let d = ds(40, 7);
        let mut r = RidgeCp::new(0.5);
        r.fit(&d);
        let probe = ds(5, 8);
        let mut xs: Vec<&[f64]> = (0..probe.n()).map(|i| probe.row(i)).collect();
        xs.push(d.row(3)); // duplicate of a training row
        let batch = r.coefficients_batch(&xs);
        assert_eq!(batch.len(), xs.len());
        for (i, &x) in xs.iter().enumerate() {
            let (sc, sa, sb) = r.coefficients(x);
            let (bc, ba, bb) = &batch[i];
            assert_eq!(sa.to_bits(), ba.to_bits(), "a i={i}");
            assert_eq!(sb.to_bits(), bb.to_bits(), "b i={i}");
            assert_eq!(sc.len(), bc.len());
            for (u, v) in sc.iter().zip(bc) {
                assert_eq!(u.0.to_bits(), v.0.to_bits(), "A_i i={i}");
                assert_eq!(u.1.to_bits(), v.1.to_bits(), "B_i i={i}");
            }
        }
        assert!(r.coefficients_batch(&[]).is_empty());
        assert_eq!(
            r.predict_region_batch(&xs[..1], 0.1),
            vec![r.predict_region(xs[0], 0.1)]
        );
        assert_eq!(
            r.p_values_batch(&xs[..1], &[probe.y[0]]),
            vec![r.p_value(xs[0], probe.y[0])]
        );
    }

    fn coefs_identical(a: &Coefficients, b: &Coefficients) -> bool {
        a.1.to_bits() == b.1.to_bits()
            && a.2.to_bits() == b.2.to_bits()
            && a.0.len() == b.0.len()
            && a.0.iter().zip(&b.0).all(|(u, v)| {
                u.0.to_bits() == v.0.to_bits() && u.1.to_bits() == v.1.to_bits()
            })
    }

    fn assert_matches_fresh(r: &RidgeCp, d: &RegressionDataset) {
        let mut fresh = RidgeCp::new(r.rho);
        fresh.fit(d);
        let probe = ds(4, 99);
        for i in 0..probe.n() {
            assert!(
                coefs_identical(
                    &r.coefficients(probe.row(i)),
                    &fresh.coefficients(probe.row(i)),
                ),
                "probe {i} diverged from fresh fit (n={})",
                d.n()
            );
        }
    }

    #[test]
    fn learn_matches_refit_bitwise() {
        let d = ds(30, 11);
        let extra = ds(5, 12);
        let mut r = RidgeCp::new(1.0);
        r.fit(&d);
        let mut grown = d.clone();
        for i in 0..extra.n() {
            assert!(r.learn(extra.row(i), extra.y[i]));
            grown.push(extra.row(i), extra.y[i]);
            assert_matches_fresh(&r, &grown);
        }
        assert_eq!(r.n(), 35);
    }

    #[test]
    fn unlearn_matches_refit_bitwise_across_checkpoints() {
        // n > 2*CKPT_EVERY so removals land before, between, and after
        // checkpoint boundaries (64, 128)
        let d = ds(150, 13);
        let mut r = RidgeCp::new(0.5);
        r.fit(&d);
        let mut reduced = d.clone();
        for idx in [149, 0, 64, 70, 128, 5] {
            assert!(r.unlearn(idx), "idx {idx}");
            reduced.remove(idx);
            assert_matches_fresh(&r, &reduced);
        }
        assert_eq!(r.n(), 144);
        assert!(!r.unlearn(144));
    }

    #[test]
    fn learn_unlearn_roundtrip_bit_identical() {
        let d = ds(64, 14); // boundary n: learn pushes a checkpoint
        let mut r = RidgeCp::new(2.0);
        r.fit(&d);
        let probe = ds(3, 15);
        let before: Vec<Coefficients> =
            (0..probe.n()).map(|i| r.coefficients(probe.row(i))).collect();
        let z = ds(1, 16);
        for _ in 0..3 {
            assert!(r.learn(z.row(0), z.y[0]));
            assert!(r.unlearn(64));
            for (i, want) in before.iter().enumerate() {
                assert!(coefs_identical(&r.coefficients(probe.row(i)), want));
            }
        }
    }

    #[test]
    fn unlearn_to_empty_and_relearn() {
        let d = ds(3, 17);
        let mut r = RidgeCp::new(1.0);
        r.fit(&d);
        assert!(r.unlearn(2));
        assert!(r.unlearn(0));
        assert!(r.unlearn(0));
        assert_eq!(r.n(), 0);
        assert!(!r.unlearn(0));
        // G = rho I stays invertible; relearning rebuilds from zero
        assert!(r.learn(d.row(0), d.y[0]));
        let mut fresh = RidgeCp::new(1.0);
        fresh.fit(&RegressionDataset::new(
            d.row(0).to_vec(),
            vec![d.y[0]],
            d.p,
        ));
        let probe = ds(2, 18);
        for i in 0..probe.n() {
            assert!(coefs_identical(
                &r.coefficients(probe.row(i)),
                &fresh.coefficients(probe.row(i)),
            ));
        }
    }

    #[test]
    fn region_covers_true_values() {
        let all = ds(150, 3);
        let mut rng = Rng::seed_from(4);
        let (train, test) = all.split(120, &mut rng);
        let mut r = RidgeCp::new(1.0);
        r.fit(&train);
        let mut covered = 0;
        for i in 0..test.n() {
            if r.predict_region(test.row(i), 0.1).contains(test.y[i]) {
                covered += 1;
            }
        }
        let rate = covered as f64 / test.n() as f64;
        assert!(rate >= 0.75, "coverage {rate}");
    }

    #[test]
    fn region_is_interval_for_well_posed_ridge() {
        // for ridge with B ~ small and b ~ 1, the region is one interval
        let d = ds(60, 5);
        let mut r = RidgeCp::new(1.0);
        r.fit(&d);
        let probe = ds(3, 6);
        for i in 0..probe.n() {
            let region = r.predict_region(probe.row(i), 0.1);
            assert!(!region.is_empty());
            assert!(region.intervals.len() <= 2, "{region:?}");
        }
    }
}
