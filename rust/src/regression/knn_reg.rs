//! Full k-NN CP regression (paper §8.1).
//!
//! Nonconformity for training example i is alpha_i(y~) = |a_i + b_i y~|
//! where the k-NN regression prediction for x_i may or may not include
//! the test object x among its k nearest neighbours:
//!
//!   x in kNN(x_i):  a_i = y_i - (1/k) sum_{j=1}^{k-1} y_(j)(x_i),  b_i = -1/k
//!   otherwise:      a_i = y_i - (1/k) sum_{j=1}^{k}   y_(j)(x_i),  b_i = 0
//!
//! and the test example has a = -(1/k) sum_{j=1}^{k} y_(j)(x), b = 1.
//!
//! * [`KnnRegressorStandard`] — the Papadopoulos et al. (2011) method:
//!   recomputes every training point's neighbourhood at prediction time;
//!   O(n^2 + 2n log 2n) per test point.
//! * [`KnnRegressorOptimized`] — our incremental&decremental version:
//!   the training phase precomputes each point's k-NN label sums and
//!   k-th distance (O(n^2) once); prediction only computes the O(n)
//!   distance row and flips the (a_i, b_i) of points whose k-NN set the
//!   test object enters — O(2n log 2n) per test point.
//!
//! Both produce the same coefficients, hence identical regions — the
//! exactness test for §8.
//!
//! Tie-breaking: neighbours are ordered by (distance, index); the test
//! object enters x_i's k-NN set iff d(x_i, x) < Delta_i^k strictly.
//! Both variants share these conventions.

use crate::data::RegressionDataset;
use crate::linalg::engine::{native, Engine};
use crate::regression::region::{conformal_region, p_value_at, Region};
use crate::regression::{Coefficients, CpRegressor};

/// Per-point neighbour statistics used by both variants.
#[derive(Clone, Debug)]
struct NnStats {
    /// sum of the labels of the k nearest neighbours
    sum_k: f64,
    /// sum of the labels of the k-1 nearest neighbours
    sum_k1: f64,
    /// distance to the k-th nearest neighbour (inf if fewer than k)
    delta_k: f64,
}

/// Compute NnStats for the point with distance row `d` (self at `skip`),
/// using (distance, index) ordering.
fn nn_stats(d: &[f64], ys: &[f64], skip: usize, k: usize) -> NnStats {
    let mut items: Vec<(f64, usize)> = (0..d.len())
        .filter(|&j| j != skip)
        .map(|j| (d[j], j))
        .collect();
    let k_eff = k.min(items.len());
    if k_eff == 0 {
        return NnStats {
            sum_k: 0.0,
            sum_k1: 0.0,
            delta_k: f64::INFINITY,
        };
    }
    items.select_nth_unstable_by(k_eff - 1, |a, b| {
        a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
    });
    items.truncate(k_eff);
    items.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    // EXACT-ALLOW: EXACT001 summation order is pinned by the total_cmp
    // sort above (distance, then index), identical on every path.
    let sum_k: f64 = items.iter().map(|&(_, j)| ys[j]).sum();
    let sum_k1 = sum_k - ys[items[k_eff - 1].1];
    let delta_k = if k_eff == k {
        items[k_eff - 1].0
    } else {
        f64::INFINITY
    };
    NnStats {
        sum_k,
        sum_k1,
        delta_k,
    }
}

/// Coefficients (a_i, b_i) for all training points + (a, b) for the test.
fn coefficients(
    stats: &[NnStats],
    d_test: &[f64],
    ds: &RegressionDataset,
    k: usize,
) -> (Vec<(f64, f64)>, f64, f64) {
    let kf = k as f64;
    let n = ds.n();
    let coefs: Vec<(f64, f64)> = (0..n)
        .map(|i| {
            let s = &stats[i];
            if d_test[i] < s.delta_k {
                // x enters x_i's k-NN set
                (ds.y[i] - s.sum_k1 / kf, -1.0 / kf)
            } else {
                (ds.y[i] - s.sum_k / kf, 0.0)
            }
        })
        .collect();
    // test coefficients: k nearest of x in Z
    let mut items: Vec<(f64, usize)> =
        d_test.iter().copied().zip(0..n).map(|(d, j)| (d, j)).collect();
    let k_eff = k.min(n);
    items.select_nth_unstable_by(k_eff - 1, |a, b| {
        a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
    });
    items.truncate(k_eff);
    // EXACT-ALLOW: EXACT001 select_nth_unstable_by is deterministic for
    // a given input and this is the only path computing the test sum,
    // so the reduction order cannot diverge between fast/naive paths.
    let sum: f64 = items.iter().map(|&(_, j)| ds.y[j]).sum();
    (coefs, -sum / kf, 1.0)
}

/// The Papadopoulos et al. (2011) full k-NN CP regressor.
pub struct KnnRegressorStandard {
    pub k: usize,
    ds: Option<RegressionDataset>,
    engine: Engine,
}

impl KnnRegressorStandard {
    pub fn new(k: usize) -> Self {
        Self::with_engine(k, native())
    }

    pub fn with_engine(k: usize, engine: Engine) -> Self {
        assert!(k >= 1);
        KnnRegressorStandard {
            k,
            ds: None,
            engine,
        }
    }

    pub fn fit(&mut self, ds: &RegressionDataset) {
        self.ds = Some(ds.clone());
    }

    pub fn n(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n())
    }

    /// Recompute every training point's neighbour statistics — the
    /// O(n^2) term the optimized variant precomputes at fit time. It is
    /// test-independent, so the batch path runs it once per batch, and
    /// the distance work is one n x n pairwise matrix launch (entries
    /// bit-identical to the per-row kernel).
    fn all_stats(&self, ds: &RegressionDataset) -> Vec<NnStats> {
        let n = ds.n();
        let mut d = self.engine.pairwise_sq(&ds.x, ds.p);
        for v in d.iter_mut() {
            *v = v.sqrt();
        }
        (0..n)
            .map(|i| nn_stats(&d[i * n..(i + 1) * n], &ds.y, i, self.k))
            .collect()
    }

    /// Affine coefficients for one test object — O(n^2) neighbour
    /// recomputation (this is exactly the term our optimization removes).
    pub fn coefficients(&self, x: &[f64]) -> Coefficients {
        let ds = self.ds.as_ref().expect("fit first");
        let stats = self.all_stats(ds);
        let mut d_test = vec![0.0; ds.n()];
        self.engine.dist_row_sq(x, &ds.x, ds.p, &mut d_test);
        for v in d_test.iter_mut() {
            *v = v.sqrt();
        }
        coefficients(&stats, &d_test, ds, self.k)
    }

    /// Batched coefficients: the O(n^2) neighbour-statistics pass is
    /// shared across the whole batch and all test distance rows come
    /// from ONE m x n matrix launch. Bit-identical to per-object
    /// [`coefficients`](Self::coefficients) (matrix entries replay the
    /// row kernel; same helpers, same order).
    pub fn coefficients_batch(&self, xs: &[&[f64]]) -> Vec<Coefficients> {
        if xs.is_empty() {
            return Vec::new();
        }
        let ds = self.ds.as_ref().expect("fit first");
        let n = ds.n();
        let stats = self.all_stats(ds);
        let mut xs_flat = Vec::with_capacity(xs.len() * ds.p);
        for x in xs {
            xs_flat.extend_from_slice(x);
        }
        let mut d_tests = vec![0.0; xs.len() * n];
        self.engine.dist_matrix_sq(&xs_flat, &ds.x, ds.p, &mut d_tests);
        for v in d_tests.iter_mut() {
            *v = v.sqrt();
        }
        (0..xs.len())
            .map(|r| coefficients(&stats, &d_tests[r * n..(r + 1) * n], ds, self.k))
            .collect()
    }

    pub fn predict_region(&self, x: &[f64], eps: f64) -> Region {
        let (coefs, a, b) = self.coefficients(x);
        conformal_region(&coefs, a, b, eps)
    }

    /// Batched regions at a shared eps; exactly equals mapping
    /// [`predict_region`](Self::predict_region) over `xs`.
    pub fn predict_region_batch(&self, xs: &[&[f64]], eps: f64) -> Vec<Region> {
        self.coefficients_batch(xs)
            .into_iter()
            .map(|(coefs, a, b)| conformal_region(&coefs, a, b, eps))
            .collect()
    }

    pub fn p_value(&self, x: &[f64], y: f64) -> f64 {
        let (coefs, a, b) = self.coefficients(x);
        p_value_at(&coefs, a, b, y)
    }

    /// Batched p-values over paired `(xs[i], ys[i])`; bit-identical to
    /// per-pair [`p_value`](Self::p_value).
    pub fn p_values_batch(&self, xs: &[&[f64]], ys: &[f64]) -> Vec<f64> {
        assert_eq!(xs.len(), ys.len());
        self.coefficients_batch(xs)
            .into_iter()
            .zip(ys)
            .map(|((coefs, a, b), &y)| p_value_at(&coefs, a, b, y))
            .collect()
    }
}

impl CpRegressor for KnnRegressorStandard {
    fn name(&self) -> String {
        format!("knn-reg-standard(k={})", self.k)
    }

    fn fit(&mut self, ds: &RegressionDataset) {
        KnnRegressorStandard::fit(self, ds)
    }

    fn coefficients(&self, x: &[f64]) -> Coefficients {
        KnnRegressorStandard::coefficients(self, x)
    }

    fn coefficients_batch(&self, xs: &[&[f64]]) -> Vec<Coefficients> {
        KnnRegressorStandard::coefficients_batch(self, xs)
    }

    fn n(&self) -> usize {
        KnnRegressorStandard::n(self)
    }

    /// The standard variant recomputes all statistics at prediction
    /// time, so online learning is just appending the example.
    fn learn(&mut self, x: &[f64], y: f64) -> bool {
        match self.ds.as_mut() {
            Some(ds) => {
                ds.push(x, y);
                true
            }
            None => false,
        }
    }

    /// ... and decremental unlearning is just dropping the row (order
    /// preserved). Trivially bit-identical to a fresh fit on the
    /// reduced set: prediction recomputes everything from `ds`.
    fn unlearn(&mut self, idx: usize) -> bool {
        match self.ds.as_mut() {
            Some(ds) if idx < ds.n() => {
                ds.remove(idx);
                true
            }
            _ => false,
        }
    }
}

/// Our incremental&decremental optimization of the k-NN CP regressor.
pub struct KnnRegressorOptimized {
    pub k: usize,
    ds: Option<RegressionDataset>,
    stats: Vec<NnStats>,
    engine: Engine,
}

impl KnnRegressorOptimized {
    pub fn new(k: usize) -> Self {
        Self::with_engine(k, native())
    }

    pub fn with_engine(k: usize, engine: Engine) -> Self {
        assert!(k >= 1);
        KnnRegressorOptimized {
            k,
            ds: None,
            stats: Vec::new(),
            engine,
        }
    }

    /// Training phase: precompute all neighbour statistics, O(n^2).
    pub fn fit(&mut self, ds: &RegressionDataset) {
        let n = ds.n();
        self.ds = Some(ds.clone());
        self.stats = Vec::with_capacity(n);
        let mut d_i = vec![0.0; n];
        for i in 0..n {
            self.engine.dist_row_sq(ds.row(i), &ds.x, ds.p, &mut d_i);
            for v in d_i.iter_mut() {
                *v = v.sqrt();
            }
            self.stats.push(nn_stats(&d_i, &ds.y, i, self.k));
        }
    }

    pub fn n(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n())
    }

    /// Prediction phase: O(n) distance row + O(n log n) sweep.
    pub fn coefficients(&self, x: &[f64]) -> Coefficients {
        let ds = self.ds.as_ref().expect("fit first");
        let mut d_test = vec![0.0; ds.n()];
        self.engine.dist_row_sq(x, &ds.x, ds.p, &mut d_test);
        for v in d_test.iter_mut() {
            *v = v.sqrt();
        }
        coefficients(&self.stats, &d_test, ds, self.k)
    }

    /// Batched coefficients: statistics are already precomputed, so the
    /// batch path is ONE m x n distance-matrix launch plus per-object
    /// assembly. Bit-identical to per-object
    /// [`coefficients`](Self::coefficients) (matrix entries replay the
    /// row kernel exactly).
    pub fn coefficients_batch(&self, xs: &[&[f64]]) -> Vec<Coefficients> {
        if xs.is_empty() {
            return Vec::new();
        }
        let ds = self.ds.as_ref().expect("fit first");
        let n = ds.n();
        let mut xs_flat = Vec::with_capacity(xs.len() * ds.p);
        for x in xs {
            xs_flat.extend_from_slice(x);
        }
        let mut d_tests = vec![0.0; xs.len() * n];
        self.engine.dist_matrix_sq(&xs_flat, &ds.x, ds.p, &mut d_tests);
        for v in d_tests.iter_mut() {
            *v = v.sqrt();
        }
        (0..xs.len())
            .map(|r| {
                coefficients(&self.stats, &d_tests[r * n..(r + 1) * n], ds, self.k)
            })
            .collect()
    }

    pub fn predict_region(&self, x: &[f64], eps: f64) -> Region {
        let (coefs, a, b) = self.coefficients(x);
        conformal_region(&coefs, a, b, eps)
    }

    /// Batched regions at a shared eps; exactly equals mapping
    /// [`predict_region`](Self::predict_region) over `xs`.
    pub fn predict_region_batch(&self, xs: &[&[f64]], eps: f64) -> Vec<Region> {
        self.coefficients_batch(xs)
            .into_iter()
            .map(|(coefs, a, b)| conformal_region(&coefs, a, b, eps))
            .collect()
    }

    pub fn p_value(&self, x: &[f64], y: f64) -> f64 {
        let (coefs, a, b) = self.coefficients(x);
        p_value_at(&coefs, a, b, y)
    }

    /// Batched p-values over paired `(xs[i], ys[i])`; bit-identical to
    /// per-pair [`p_value`](Self::p_value).
    pub fn p_values_batch(&self, xs: &[&[f64]], ys: &[f64]) -> Vec<f64> {
        assert_eq!(xs.len(), ys.len());
        self.coefficients_batch(xs)
            .into_iter()
            .zip(ys)
            .map(|((coefs, a, b), &y)| p_value_at(&coefs, a, b, y))
            .collect()
    }

    /// Online increment (§9): add (x, y) in O(n) + O(k) per affected row.
    pub fn learn(&mut self, x: &[f64], y: f64) {
        let Some(ds) = self.ds.as_mut() else { return };
        let n = ds.n();
        let mut d = vec![0.0; n];
        self.engine.dist_row_sq(x, &ds.x, ds.p, &mut d);
        for v in d.iter_mut() {
            *v = v.sqrt();
        }
        ds.push(x, y);
        // rows whose k-NN set the new point enters must be recomputed;
        // underfull rows always change
        let ds = self.ds.as_ref().unwrap();
        let mut d_i = vec![0.0; ds.n()];
        for i in 0..n {
            if d[i] < self.stats[i].delta_k {
                self.engine.dist_row_sq(ds.row(i), &ds.x, ds.p, &mut d_i);
                for v in d_i.iter_mut() {
                    *v = v.sqrt();
                }
                self.stats[i] = nn_stats(&d_i, &ds.y, i, self.k);
            }
        }
        // stats for the new row
        let mut d_new = vec![0.0; ds.n()];
        self.engine.dist_row_sq(ds.row(n), &ds.x, ds.p, &mut d_new);
        for v in d_new.iter_mut() {
            *v = v.sqrt();
        }
        self.stats.push(nn_stats(&d_new, &ds.y, n, self.k));
    }

    /// Online decrement (the paper's removal step applied to §8.1):
    /// drop training row `idx` and rebuild the neighbour statistics of
    /// every row whose k-NN set could have contained it — the same
    /// rebuild-row pattern as the classification measure's unlearn
    /// (`measures/knn.rs`). Bit-identical to a fresh fit on the reduced
    /// set: [`nn_stats`] sums labels in sorted `(distance, index)`
    /// order — a canonical order that the uniform index shift of the
    /// surviving rows preserves — so untouched rows keep fit-equal
    /// bits and rebuilt rows replay the fit computation on the same
    /// reduced distance row.
    pub fn unlearn(&mut self, idx: usize) -> bool {
        let Some(ds) = self.ds.as_mut() else {
            return false;
        };
        if idx >= ds.n() {
            return false;
        }
        // distances from the removed point to everyone (cheap k-NN
        // membership test; sq_dist is bitwise symmetric)
        let x_rm = ds.row(idx).to_vec();
        let mut d = vec![0.0; ds.n()];
        self.engine.dist_row_sq(&x_rm, &ds.x, ds.p, &mut d);
        for v in d.iter_mut() {
            *v = v.sqrt();
        }
        ds.remove(idx);
        self.stats.remove(idx);
        // note: d still indexed by OLD rows; map old j -> new row.
        // `<=` catches the removed point tied at the k-th distance
        // (conservative: rebuilding an unaffected row is exact because
        // nn_stats is canonical); underfull rows have delta_k = inf and
        // always rebuild.
        let stale: Vec<usize> = (0..d.len())
            .filter(|&j| j != idx)
            .filter(|&j| {
                let new_j = if j > idx { j - 1 } else { j };
                d[j] <= self.stats[new_j].delta_k
            })
            .map(|j| if j > idx { j - 1 } else { j })
            .collect();
        let ds = self.ds.as_ref().unwrap();
        let mut d_i = vec![0.0; ds.n()];
        for i in stale {
            self.engine.dist_row_sq(ds.row(i), &ds.x, ds.p, &mut d_i);
            for v in d_i.iter_mut() {
                *v = v.sqrt();
            }
            self.stats[i] = nn_stats(&d_i, &ds.y, i, self.k);
        }
        true
    }
}

impl CpRegressor for KnnRegressorOptimized {
    fn name(&self) -> String {
        format!("knn-reg(k={})", self.k)
    }

    fn fit(&mut self, ds: &RegressionDataset) {
        KnnRegressorOptimized::fit(self, ds)
    }

    fn coefficients(&self, x: &[f64]) -> Coefficients {
        KnnRegressorOptimized::coefficients(self, x)
    }

    fn coefficients_batch(&self, xs: &[&[f64]]) -> Vec<Coefficients> {
        KnnRegressorOptimized::coefficients_batch(self, xs)
    }

    fn n(&self) -> usize {
        KnnRegressorOptimized::n(self)
    }

    fn learn(&mut self, x: &[f64], y: f64) -> bool {
        if self.ds.is_none() {
            return false;
        }
        KnnRegressorOptimized::learn(self, x, y);
        true
    }

    fn unlearn(&mut self, idx: usize) -> bool {
        KnnRegressorOptimized::unlearn(self, idx)
    }
}

/// Inductive k-NN regression baseline (Papadopoulos et al. 2002):
/// k-NN point prediction from the proper training set, calibration by
/// absolute residuals, symmetric interval at the (1-eps) quantile.
pub struct IcpKnnRegressor {
    pub k: usize,
    proper: Option<RegressionDataset>,
    calib: Vec<f64>,
    engine: Engine,
}

impl IcpKnnRegressor {
    pub fn new(k: usize) -> Self {
        IcpKnnRegressor {
            k,
            proper: None,
            calib: Vec::new(),
            engine: native(),
        }
    }

    /// k-NN point prediction against the proper training set.
    pub fn point_predict(&self, x: &[f64]) -> f64 {
        let ds = self.proper.as_ref().expect("fit first");
        let mut d = vec![0.0; ds.n()];
        self.engine.dist_row_sq(x, &ds.x, ds.p, &mut d);
        let mut items: Vec<(f64, usize)> =
            d.iter().copied().zip(0..ds.n()).collect();
        let k_eff = self.k.min(items.len());
        items.select_nth_unstable_by(k_eff - 1, |a, b| {
            a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
        });
        items.truncate(k_eff);
        // EXACT-ALLOW: EXACT001 select_nth_unstable_by is deterministic
        // for a given input and this is the only point-prediction path,
        // so the reduction order cannot diverge across runs.
        items.iter().map(|&(_, j)| ds.y[j]).sum::<f64>() / k_eff as f64
    }

    /// Split-fit: first `t` rows proper, rest calibration.
    pub fn fit(&mut self, ds: &RegressionDataset, t: usize) {
        assert!(t >= 1 && t < ds.n());
        let proper = RegressionDataset::new(
            ds.x[..t * ds.p].to_vec(),
            ds.y[..t].to_vec(),
            ds.p,
        );
        self.proper = Some(proper);
        self.calib = (t..ds.n())
            .map(|i| (ds.y[i] - self.point_predict(ds.row(i))).abs())
            .collect();
        self.calib.sort_unstable_by(|a, b| a.total_cmp(b));
    }

    /// Symmetric ICP interval.
    pub fn predict_interval(&self, x: &[f64], eps: f64) -> (f64, f64) {
        let c = self.calib.len();
        let yhat = self.point_predict(x);
        // quantile index: smallest q with (#{alpha_i >= q}+1)/(c+1) <= eps
        let rank = ((1.0 - eps) * (c + 1) as f64).ceil() as usize;
        if rank > c {
            return (f64::NEG_INFINITY, f64::INFINITY);
        }
        let q = self.calib[rank - 1];
        (yhat - q, yhat + q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_regression, RegressionSpec, Rng};

    fn ds(n: usize, seed: u64) -> RegressionDataset {
        make_regression(
            &RegressionSpec {
                n_samples: n,
                n_features: 6,
                n_informative: 3,
                noise: 5.0,
            },
            seed,
        )
    }

    #[test]
    fn optimized_coefficients_match_standard() {
        let d = ds(50, 1);
        let mut s = KnnRegressorStandard::new(5);
        let mut o = KnnRegressorOptimized::new(5);
        s.fit(&d);
        o.fit(&d);
        let probe = ds(10, 2);
        for i in 0..probe.n() {
            let (ca, aa, ba) = s.coefficients(probe.row(i));
            let (cb, ab, bb) = o.coefficients(probe.row(i));
            assert_eq!(ca, cb);
            assert_eq!((aa, ba), (ab, bb));
        }
    }

    #[test]
    fn regions_match_between_variants() {
        let d = ds(40, 3);
        let mut s = KnnRegressorStandard::new(3);
        let mut o = KnnRegressorOptimized::new(3);
        s.fit(&d);
        o.fit(&d);
        let probe = ds(5, 4);
        for i in 0..probe.n() {
            let ra = s.predict_region(probe.row(i), 0.1);
            let rb = o.predict_region(probe.row(i), 0.1);
            assert_eq!(ra, rb);
        }
    }

    #[test]
    fn region_covers_plausible_label() {
        // the true generating value should usually be inside a 90% region
        let all = ds(120, 5);
        let mut rng = Rng::seed_from(6);
        let (train, test) = all.split(100, &mut rng);
        let mut o = KnnRegressorOptimized::new(5);
        o.fit(&train);
        let mut covered = 0;
        for i in 0..test.n() {
            if o.predict_region(test.row(i), 0.1).contains(test.y[i]) {
                covered += 1;
            }
        }
        let rate = covered as f64 / test.n() as f64;
        assert!(rate >= 0.7, "coverage {rate}");
    }

    #[test]
    fn pvalue_of_kth_neighbor_label_reasonable() {
        let d = ds(30, 7);
        let mut o = KnnRegressorOptimized::new(3);
        o.fit(&d);
        // p-value must be in (0, 1]
        let p = o.p_value(d.row(0), d.y[0]);
        assert!(p > 0.0 && p <= 1.0);
    }

    #[test]
    fn learn_matches_refit() {
        let d = ds(30, 8);
        let extra = ds(5, 9);
        let mut inc = KnnRegressorOptimized::new(3);
        inc.fit(&d);
        let mut grown = d.clone();
        for i in 0..extra.n() {
            inc.learn(extra.row(i), extra.y[i]);
            grown.x.extend_from_slice(extra.row(i));
            grown.y.push(extra.y[i]);
        }
        let mut refit = KnnRegressorOptimized::new(3);
        refit.fit(&grown);
        let probe = ds(4, 10);
        for i in 0..probe.n() {
            assert_eq!(
                inc.coefficients(probe.row(i)),
                refit.coefficients(probe.row(i))
            );
        }
    }

    fn coefs_identical(a: &Coefficients, b: &Coefficients) -> bool {
        a.1.to_bits() == b.1.to_bits()
            && a.2.to_bits() == b.2.to_bits()
            && a.0.len() == b.0.len()
            && a.0.iter().zip(&b.0).all(|(u, v)| {
                u.0.to_bits() == v.0.to_bits() && u.1.to_bits() == v.1.to_bits()
            })
    }

    #[test]
    fn batch_coefficients_bitwise_identical_both_variants() {
        let d = ds(45, 20);
        let mut s = KnnRegressorStandard::new(4);
        let mut o = KnnRegressorOptimized::new(4);
        s.fit(&d);
        o.fit(&d);
        let probe = ds(6, 21);
        // include a probe that duplicates a training row (zero-distance
        // ties exercise the strict `<` neighbour-entry rule)
        let mut xs: Vec<&[f64]> = (0..probe.n()).map(|i| probe.row(i)).collect();
        xs.push(d.row(0));
        let bs = s.coefficients_batch(&xs);
        let bo = o.coefficients_batch(&xs);
        assert_eq!(bs.len(), xs.len());
        for (i, &x) in xs.iter().enumerate() {
            assert!(coefs_identical(&bs[i], &s.coefficients(x)), "std i={i}");
            assert!(coefs_identical(&bo[i], &o.coefficients(x)), "opt i={i}");
        }
    }

    #[test]
    fn batch_empty_and_singleton() {
        let d = ds(20, 22);
        let mut s = KnnRegressorStandard::new(3);
        let mut o = KnnRegressorOptimized::new(3);
        s.fit(&d);
        o.fit(&d);
        assert!(s.coefficients_batch(&[]).is_empty());
        assert!(o.coefficients_batch(&[]).is_empty());
        assert!(s.predict_region_batch(&[], 0.1).is_empty());
        let probe = ds(1, 23);
        let xs: Vec<&[f64]> = vec![probe.row(0)];
        assert_eq!(
            s.predict_region_batch(&xs, 0.1),
            vec![s.predict_region(probe.row(0), 0.1)]
        );
        assert_eq!(
            o.p_values_batch(&xs, &[probe.y[0]]),
            vec![o.p_value(probe.row(0), probe.y[0])]
        );
    }

    #[test]
    fn trait_learn_matches_refit_standard() {
        let d = ds(25, 24);
        let extra = ds(4, 25);
        let mut inc = KnnRegressorStandard::new(3);
        assert!(!CpRegressor::learn(&mut inc, extra.row(0), extra.y[0]));
        inc.fit(&d);
        let mut grown = d.clone();
        for i in 0..extra.n() {
            assert!(CpRegressor::learn(&mut inc, extra.row(i), extra.y[i]));
            grown.x.extend_from_slice(extra.row(i));
            grown.y.push(extra.y[i]);
        }
        assert_eq!(inc.n(), grown.n());
        let mut refit = KnnRegressorStandard::new(3);
        refit.fit(&grown);
        let probe = ds(3, 26);
        for i in 0..probe.n() {
            assert_eq!(
                inc.coefficients(probe.row(i)),
                refit.coefficients(probe.row(i))
            );
        }
    }

    #[test]
    fn unlearn_matches_refit_bitwise_optimized() {
        let d = ds(40, 30);
        let mut dec = KnnRegressorOptimized::new(3);
        dec.fit(&d);
        let mut reduced = d.clone();
        let probe = ds(5, 31);
        for idx in [39, 0, 17, 0] {
            assert!(dec.unlearn(idx), "idx {idx}");
            reduced.remove(idx);
            let mut fresh = KnnRegressorOptimized::new(3);
            fresh.fit(&reduced);
            for i in 0..probe.n() {
                assert!(
                    coefs_identical(
                        &dec.coefficients(probe.row(i)),
                        &fresh.coefficients(probe.row(i)),
                    ),
                    "idx {idx} probe {i}"
                );
            }
        }
        assert_eq!(dec.n(), 36);
        assert!(!dec.unlearn(36));
    }

    #[test]
    fn learn_unlearn_roundtrip_bit_identical_all_kinds() {
        let d = ds(25, 32);
        let z = ds(1, 33);
        let probe = ds(4, 34);
        let mut o = KnnRegressorOptimized::new(3);
        let mut s = KnnRegressorStandard::new(3);
        o.fit(&d);
        s.fit(&d);
        for m in [&mut o as &mut dyn CpRegressor, &mut s] {
            let before: Vec<Coefficients> =
                (0..probe.n()).map(|i| m.coefficients(probe.row(i))).collect();
            assert!(m.learn(z.row(0), z.y[0]));
            assert!(m.unlearn(25));
            assert_eq!(m.n(), 25);
            for (i, want) in before.iter().enumerate() {
                assert!(
                    coefs_identical(&m.coefficients(probe.row(i)), want),
                    "{} probe {i}",
                    m.name()
                );
            }
        }
    }

    #[test]
    fn unlearn_below_k_training_examples() {
        // shrink past n = k: delta_k goes infinite, every row rebuilds
        let d = ds(5, 35);
        let mut dec = KnnRegressorOptimized::new(3);
        dec.fit(&d);
        let mut reduced = d.clone();
        for _ in 0..4 {
            assert!(dec.unlearn(0));
            reduced.remove(0);
            let mut fresh = KnnRegressorOptimized::new(3);
            fresh.fit(&reduced);
            let probe = ds(2, 36);
            for i in 0..probe.n() {
                assert!(coefs_identical(
                    &dec.coefficients(probe.row(i)),
                    &fresh.coefficients(probe.row(i)),
                ));
            }
        }
        assert_eq!(dec.n(), 1);
    }

    #[test]
    fn icp_interval_contains_point_prediction() {
        let d = ds(100, 11);
        let mut icp = IcpKnnRegressor::new(5);
        icp.fit(&d, 50);
        let probe = ds(5, 12);
        for i in 0..probe.n() {
            let (lo, hi) = icp.predict_interval(probe.row(i), 0.1);
            let yhat = icp.point_predict(probe.row(i));
            assert!(lo <= yhat && yhat <= hi);
        }
    }

    #[test]
    fn icp_interval_widens_with_confidence() {
        let d = ds(100, 13);
        let mut icp = IcpKnnRegressor::new(5);
        icp.fit(&d, 50);
        let x = ds(1, 14);
        let (l90, h90) = icp.predict_interval(x.row(0), 0.1);
        let (l99, h99) = icp.predict_interval(x.row(0), 0.01);
        assert!(h99 - l99 >= h90 - l90);
    }
}
