//! Full CP regression (paper §8) and baselines.
//!
//! - [`region`] — the exact critical-point sweep shared by all affine-
//!   score CP regressors;
//! - [`knn_reg`] — the Papadopoulos et al. (2011) k-NN CP regressor, our
//!   incremental&decremental optimization of it (§8.1), and the ICP
//!   regression baseline;
//! - [`ridge`] — the ridge (RRCM) full CP regressor with incremental
//!   Sherman–Morrison updates (the §8 "Discussion" extension).
//!
//! # Batched coefficient layout
//!
//! Every full-CP regressor reduces a test object `x` to affine score
//! coefficients: per-training rays `coefs[i] = (a_i, b_i)` with
//! `alpha_i(y~) = |a_i + b_i y~|`, plus the test ray `(a, b)`. The batch
//! entry point is [`CpRegressor::coefficients_batch`]: given `xs`, it
//! returns one `(coefs, a, b)` triple per test object, in input order —
//! the same "one expensive row per object, shared precomputation per
//! batch" axis as `CpMeasure::scores_batch` on the classification side:
//!
//! * **k-NN, standard** — the O(n^2) neighbour-statistics pass is
//!   test-independent, so a batch computes it ONCE instead of once per
//!   object (the per-object cost drops to one distance row + assembly);
//! * **k-NN, optimized** — statistics are precomputed at fit time; the
//!   batch path reuses one distance-row buffer across objects;
//! * **ridge** — `M0 (X^T Y)` does not depend on the test object and is
//!   hoisted out of the per-object Sherman–Morrison application.
//!
//! Downstream consumers ([`CpRegressor::predict_region_batch`],
//! `Deployment::region_rows` in the coordinator) feed each triple to
//! [`region::conformal_region`] per object — eps may differ per object
//! because only the sweep, never the coefficients, depends on it.
//!
//! # Exactness contract
//!
//! Batched outputs are **bitwise identical** to the single-object path:
//! for every `i`, `coefficients_batch(xs)[i]` must equal
//! `coefficients(xs[i])` bit for bit (and hence regions and p-values
//! computed from them are identical, not merely close). The contract is
//! enforced by the batch-vs-single proptests in `rust/tests/proptests.rs`,
//! pinned by the golden interval fixtures in
//! `rust/tests/golden_regions.rs` (expected intervals from an
//! independent Python reference), and asserted before timing by
//! `rust/benches/batch_regression.rs`.
//!
//! The same contract extends to the online path: after
//! [`CpRegressor::learn`] / [`CpRegressor::unlearn`] every served value
//! must be bit-identical to a fresh fit on the grown/reduced training
//! set (EXACTNESS.md "Decremental paths"; locked by the learn/unlearn
//! round-trip proptests and `benches/online_unlearn.rs`).

pub mod knn_reg;
pub mod region;
pub mod ridge;

pub use knn_reg::{IcpKnnRegressor, KnnRegressorOptimized, KnnRegressorStandard};
pub use region::{conformal_region, p_value_at, Interval, Region};
pub use ridge::RidgeCp;

use crate::data::RegressionDataset;

/// One test object's affine score coefficients:
/// `(per-training (a_i, b_i) rays, a, b)` with scores `|a_i + b_i y~|`
/// for training examples and `|a + b y~|` for the test example.
pub type Coefficients = (Vec<(f64, f64)>, f64, f64);

/// A full-CP regressor usable by the serving coordinator: anything that
/// maps a test object to affine score coefficients (see the module docs
/// for the layout and the batched exactness contract).
///
/// `Send + Sync` so regression deployments can sit behind the
/// coordinator's RwLock and be scored from a worker pool (the scoring
/// methods take `&self`).
pub trait CpRegressor: Send + Sync {
    /// Human-readable regressor name (CLI, benches, error messages).
    fn name(&self) -> String;

    /// Train/precompute on the training bag.
    fn fit(&mut self, ds: &RegressionDataset);

    /// Affine score coefficients for one test object:
    /// `(per-training (a_i, b_i), a, b)`.
    fn coefficients(&self, x: &[f64]) -> Coefficients;

    /// Batched coefficients, one triple per test object in input order.
    ///
    /// **Contract: identical output to per-object [`coefficients`]** —
    /// `coefficients_batch(xs)[i]` equals `coefficients(xs[i])` bit for
    /// bit. The default implementation trivially satisfies this by
    /// looping; specialized implementations share the test-independent
    /// precomputation across the batch (see the module docs).
    ///
    /// [`coefficients`]: CpRegressor::coefficients
    fn coefficients_batch(&self, xs: &[&[f64]]) -> Vec<Coefficients> {
        xs.iter().map(|x| self.coefficients(x)).collect()
    }

    /// Exact prediction region { y~ : p(y~) > eps } for one object.
    fn predict_region(&self, x: &[f64], eps: f64) -> Region {
        let (coefs, a, b) = self.coefficients(x);
        conformal_region(&coefs, a, b, eps)
    }

    /// Batched regions at a shared eps; equals per-object
    /// [`predict_region`] exactly (it consumes
    /// [`coefficients_batch`], which is bit-identical by contract).
    ///
    /// [`predict_region`]: CpRegressor::predict_region
    /// [`coefficients_batch`]: CpRegressor::coefficients_batch
    fn predict_region_batch(&self, xs: &[&[f64]], eps: f64) -> Vec<Region> {
        self.coefficients_batch(xs)
            .into_iter()
            .map(|(coefs, a, b)| conformal_region(&coefs, a, b, eps))
            .collect()
    }

    /// Exact conformal p-value of the candidate label `y` for `x`.
    fn p_value(&self, x: &[f64], y: f64) -> f64 {
        let (coefs, a, b) = self.coefficients(x);
        p_value_at(&coefs, a, b, y)
    }

    /// Batched p-values over paired `(xs[i], ys[i])`; bit-identical to
    /// per-pair [`p_value`].
    ///
    /// [`p_value`]: CpRegressor::p_value
    fn p_values_batch(&self, xs: &[&[f64]], ys: &[f64]) -> Vec<f64> {
        assert_eq!(xs.len(), ys.len());
        self.coefficients_batch(xs)
            .into_iter()
            .zip(ys)
            .map(|((coefs, a, b), &y)| p_value_at(&coefs, a, b, y))
            .collect()
    }

    /// Number of training examples currently fitted.
    fn n(&self) -> usize;

    /// Incrementally learn one example (online setting, §9). Returns
    /// false when the regressor does not support online updates.
    fn learn(&mut self, _x: &[f64], _y: f64) -> bool {
        false
    }

    /// Decrementally unlearn the training example at `idx` (the paper's
    /// removal step, §4/§8). Returns false when the regressor does not
    /// support decremental updates or `idx` is out of range.
    ///
    /// **Contract: bit-exact.** After `unlearn(idx)` every served value
    /// (coefficients, regions, p-values) must be bit-identical to a
    /// regressor freshly fitted on the training set with row `idx`
    /// removed (order otherwise preserved) — see EXACTNESS.md
    /// "Decremental paths". Enforced by the round-trip proptests in
    /// `rust/tests/proptests.rs` and `benches/online_unlearn.rs`.
    fn unlearn(&mut self, _idx: usize) -> bool {
        false
    }
}

/// Boxed regressors forward every method — including the batch entry
/// points, so a `Box<dyn CpRegressor>` keeps its concrete type's
/// specialized batch path (mirrors the `CpMeasure` forwarding impl).
impl<R: CpRegressor + ?Sized> CpRegressor for Box<R> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn fit(&mut self, ds: &RegressionDataset) {
        (**self).fit(ds)
    }

    fn coefficients(&self, x: &[f64]) -> Coefficients {
        (**self).coefficients(x)
    }

    fn coefficients_batch(&self, xs: &[&[f64]]) -> Vec<Coefficients> {
        (**self).coefficients_batch(xs)
    }

    fn predict_region(&self, x: &[f64], eps: f64) -> Region {
        (**self).predict_region(x, eps)
    }

    fn predict_region_batch(&self, xs: &[&[f64]], eps: f64) -> Vec<Region> {
        (**self).predict_region_batch(xs, eps)
    }

    fn p_value(&self, x: &[f64], y: f64) -> f64 {
        (**self).p_value(x, y)
    }

    fn p_values_batch(&self, xs: &[&[f64]], ys: &[f64]) -> Vec<f64> {
        (**self).p_values_batch(xs, ys)
    }

    fn n(&self) -> usize {
        (**self).n()
    }

    fn learn(&mut self, x: &[f64], y: f64) -> bool {
        (**self).learn(x, y)
    }

    fn unlearn(&mut self, idx: usize) -> bool {
        (**self).unlearn(idx)
    }
}
