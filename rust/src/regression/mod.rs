//! Full CP regression (paper §8) and baselines.
//!
//! - [`region`] — the exact critical-point sweep shared by all affine-
//!   score CP regressors;
//! - [`knn_reg`] — the Papadopoulos et al. (2011) k-NN CP regressor, our
//!   incremental&decremental optimization of it (§8.1), and the ICP
//!   regression baseline;
//! - [`ridge`] — the ridge (RRCM) full CP regressor with incremental
//!   Sherman–Morrison updates (the §8 "Discussion" extension).

pub mod knn_reg;
pub mod region;
pub mod ridge;

pub use knn_reg::{IcpKnnRegressor, KnnRegressorOptimized, KnnRegressorStandard};
pub use region::{conformal_region, p_value_at, Interval, Region};
pub use ridge::RidgeCp;
