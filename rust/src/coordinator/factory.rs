//! Measure factory: configuration -> boxed nonconformity measure.

use std::sync::Arc;

use crate::config::{MeasureConfig, MeasureKind};
use crate::cp::measure::CpMeasure;
use crate::linalg::engine::Engine;
use crate::measures::{
    BootstrapOptimized, BootstrapParams, BootstrapStandard, FeatureMap,
    KdeOptimized, KdeStandard, KnnOptimized, KnnStandard, LsSvmOptimized,
    LsSvmStandard,
};
use crate::runtime::{PjrtEngine, PjrtRuntime};

fn feature_map(cfg: &MeasureConfig) -> FeatureMap {
    if cfg.rff_dim == 0 {
        FeatureMap::Linear
    } else {
        FeatureMap::Rff {
            q: cfg.rff_dim,
            gamma: cfg.rff_gamma,
            seed: 7,
        }
    }
}

/// Build an *optimized* measure (the serving default).
pub fn build_measure(
    kind: MeasureKind,
    cfg: &MeasureConfig,
    engine: Option<Engine>,
) -> Box<dyn CpMeasure> {
    let eng = engine.unwrap_or_else(crate::linalg::engine::native);
    match kind {
        MeasureKind::Knn => Box::new(KnnOptimized::with_engine(cfg.k, false, eng)),
        MeasureKind::SimplifiedKnn => {
            Box::new(KnnOptimized::with_engine(cfg.k, true, eng))
        }
        MeasureKind::Kde => Box::new(KdeOptimized::with_engine(cfg.h, eng)),
        MeasureKind::LsSvm => {
            Box::new(LsSvmOptimized::new(cfg.rho, feature_map(cfg)))
        }
        MeasureKind::RandomForest => Box::new(BootstrapOptimized::new(
            BootstrapParams {
                b: cfg.b,
                ..Default::default()
            },
        )),
    }
}

/// Build a *standard* (unoptimized) measure — the paper's baselines.
pub fn build_standard_measure(
    kind: MeasureKind,
    cfg: &MeasureConfig,
) -> Box<dyn CpMeasure> {
    match kind {
        MeasureKind::Knn => Box::new(KnnStandard::new(cfg.k, false)),
        MeasureKind::SimplifiedKnn => Box::new(KnnStandard::new(cfg.k, true)),
        MeasureKind::Kde => Box::new(KdeStandard::new(cfg.h)),
        MeasureKind::LsSvm => {
            Box::new(LsSvmStandard::new(cfg.rho, feature_map(cfg)))
        }
        MeasureKind::RandomForest => Box::new(BootstrapStandard::new(
            BootstrapParams {
                b: cfg.b,
                ..Default::default()
            },
        )),
    }
}

/// Engine selection honouring `use_pjrt` (falls back to native with a
/// warning when artifacts are missing).
pub fn select_engine(use_pjrt: bool, artifacts_dir: &str) -> Engine {
    if use_pjrt {
        match PjrtRuntime::open(artifacts_dir) {
            Ok(rt) => return Arc::new(PjrtEngine::new(Arc::new(rt))),
            Err(e) => eprintln!(
                "warning: use_pjrt requested but artifacts unavailable \
                 ({e}); falling back to the native engine"
            ),
        }
    }
    crate::linalg::engine::native()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_classification, ClassificationSpec};

    #[test]
    fn factory_builds_every_kind() {
        let cfg = MeasureConfig::default();
        let ds = make_classification(
            &ClassificationSpec {
                n_samples: 24,
                ..Default::default()
            },
            1,
        );
        for kind in MeasureKind::all() {
            let mut m = build_measure(kind, &cfg, None);
            m.fit(&ds);
            let s = m.scores(ds.row(0), 0);
            assert_eq!(s.train.len(), 24, "{}", m.name());
        }
    }

    #[test]
    fn standard_factory_builds_every_kind() {
        let cfg = MeasureConfig {
            b: 3,
            ..Default::default()
        };
        let ds = make_classification(
            &ClassificationSpec {
                n_samples: 10,
                ..Default::default()
            },
            2,
        );
        for kind in MeasureKind::all() {
            let mut m = build_standard_measure(kind, &cfg);
            m.fit(&ds);
            let s = m.scores(ds.row(0), 1);
            assert_eq!(s.train.len(), 10, "{}", m.name());
        }
    }

    #[test]
    fn select_engine_falls_back() {
        let eng = select_engine(true, "/nonexistent/artifacts");
        assert_eq!(eng.name(), "native");
    }
}
