//! Measure factory: configuration -> boxed nonconformity measure
//! (classification) or boxed CP regressor (regression), plus the
//! `[serve.deployment.X]` spec resolver.

use std::sync::Arc;

use crate::config::{DeploymentSpec, MeasureConfig, MeasureKind, RegressorKind};
use crate::coordinator::state::Deployment;
use crate::cp::measure::CpMeasure;
use crate::data::{Dataset, RegressionDataset};
use crate::linalg::engine::Engine;
use crate::measures::{
    BootstrapOptimized, BootstrapParams, BootstrapStandard, FeatureMap,
    KdeOptimized, KdeStandard, KnnOptimized, KnnStandard, LsSvmOptimized,
    LsSvmStandard,
};
use crate::regression::{
    CpRegressor, KnnRegressorOptimized, KnnRegressorStandard, RidgeCp,
};
use crate::runtime::{PjrtEngine, PjrtRuntime};

fn feature_map(cfg: &MeasureConfig) -> FeatureMap {
    if cfg.rff_dim == 0 {
        FeatureMap::Linear
    } else {
        FeatureMap::Rff {
            q: cfg.rff_dim,
            gamma: cfg.rff_gamma,
            seed: 7,
        }
    }
}

/// Build an *optimized* measure (the serving default).
pub fn build_measure(
    kind: MeasureKind,
    cfg: &MeasureConfig,
    engine: Option<Engine>,
) -> Box<dyn CpMeasure> {
    let eng = engine.unwrap_or_else(crate::linalg::engine::native);
    match kind {
        MeasureKind::Knn => Box::new(KnnOptimized::with_engine(cfg.k, false, eng)),
        MeasureKind::SimplifiedKnn => {
            Box::new(KnnOptimized::with_engine(cfg.k, true, eng))
        }
        MeasureKind::Kde => Box::new(KdeOptimized::with_engine(cfg.h, eng)),
        MeasureKind::LsSvm => {
            Box::new(LsSvmOptimized::new(cfg.rho, feature_map(cfg)))
        }
        MeasureKind::RandomForest => Box::new(BootstrapOptimized::new(
            BootstrapParams {
                b: cfg.b,
                ..Default::default()
            },
        )),
    }
}

/// Build a *standard* (unoptimized) measure — the paper's baselines.
pub fn build_standard_measure(
    kind: MeasureKind,
    cfg: &MeasureConfig,
) -> Box<dyn CpMeasure> {
    match kind {
        MeasureKind::Knn => Box::new(KnnStandard::new(cfg.k, false)),
        MeasureKind::SimplifiedKnn => Box::new(KnnStandard::new(cfg.k, true)),
        MeasureKind::Kde => Box::new(KdeStandard::new(cfg.h)),
        MeasureKind::LsSvm => {
            Box::new(LsSvmStandard::new(cfg.rho, feature_map(cfg)))
        }
        MeasureKind::RandomForest => Box::new(BootstrapStandard::new(
            BootstrapParams {
                b: cfg.b,
                ..Default::default()
            },
        )),
    }
}

/// Build a CP regressor (k from `cfg.k`, rho from `cfg.rho`).
pub fn build_regressor(
    kind: RegressorKind,
    cfg: &MeasureConfig,
    engine: Option<Engine>,
) -> Box<dyn CpRegressor> {
    let eng = engine.unwrap_or_else(crate::linalg::engine::native);
    match kind {
        RegressorKind::Knn => {
            Box::new(KnnRegressorOptimized::with_engine(cfg.k, eng))
        }
        RegressorKind::KnnStandard => {
            Box::new(KnnRegressorStandard::with_engine(cfg.k, eng))
        }
        RegressorKind::Ridge => Box::new(RidgeCp::new(cfg.rho)),
    }
}

/// Train one `[serve.deployment.X]` spec into a deployment. The spec's
/// `kind` string is tried as a classification measure first, then as a
/// regressor; each spec carries its *own* `MeasureConfig` (k, ridge
/// rho, bandwidth, ...), so deployments of the same kind can serve
/// different hyperparameters side by side.
pub fn deployment_from_spec(
    spec: &DeploymentSpec,
    cls: &Dataset,
    reg: &RegressionDataset,
    engine: Option<Engine>,
) -> anyhow::Result<Deployment> {
    if let Ok(kind) = spec.kind.parse::<MeasureKind>() {
        return Ok(Deployment::train(
            &spec.name,
            kind,
            &spec.measure,
            cls,
            engine,
        ));
    }
    match spec.kind.parse::<RegressorKind>() {
        Ok(kind) => Ok(Deployment::train_regression(
            &spec.name,
            kind,
            &spec.measure,
            reg,
            engine,
        )),
        Err(_) => anyhow::bail!(
            "deployment {:?}: kind {:?} is neither a measure nor a \
             regressor",
            spec.name,
            spec.kind
        ),
    }
}

/// Engine selection honouring `use_pjrt` (falls back to native with a
/// warning when artifacts are missing). `dist_workers` sets the scoped
/// thread count for native distance-matrix launches; output bytes are
/// identical for every worker count.
pub fn select_engine(
    use_pjrt: bool,
    artifacts_dir: &str,
    dist_workers: usize,
) -> Engine {
    if use_pjrt {
        match PjrtRuntime::open(artifacts_dir) {
            Ok(rt) => return Arc::new(PjrtEngine::new(Arc::new(rt))),
            Err(e) => eprintln!(
                "warning: use_pjrt requested but artifacts unavailable \
                 ({e}); falling back to the native engine"
            ),
        }
    }
    crate::linalg::engine::native_with_workers(dist_workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_classification, ClassificationSpec};

    #[test]
    fn factory_builds_every_kind() {
        let cfg = MeasureConfig::default();
        let ds = make_classification(
            &ClassificationSpec {
                n_samples: 24,
                ..Default::default()
            },
            1,
        );
        for kind in MeasureKind::all() {
            let mut m = build_measure(kind, &cfg, None);
            m.fit(&ds);
            let s = m.scores(ds.row(0), 0);
            assert_eq!(s.train.len(), 24, "{}", m.name());
        }
    }

    #[test]
    fn standard_factory_builds_every_kind() {
        let cfg = MeasureConfig {
            b: 3,
            ..Default::default()
        };
        let ds = make_classification(
            &ClassificationSpec {
                n_samples: 10,
                ..Default::default()
            },
            2,
        );
        for kind in MeasureKind::all() {
            let mut m = build_standard_measure(kind, &cfg);
            m.fit(&ds);
            let s = m.scores(ds.row(0), 1);
            assert_eq!(s.train.len(), 10, "{}", m.name());
        }
    }

    #[test]
    fn regressor_factory_builds_every_kind() {
        use crate::data::{make_regression, RegressionSpec};
        let cfg = MeasureConfig {
            k: 3,
            ..Default::default()
        };
        let ds = make_regression(
            &RegressionSpec {
                n_samples: 20,
                n_features: 4,
                n_informative: 3,
                noise: 2.0,
            },
            3,
        );
        for kind in RegressorKind::all() {
            let mut r = build_regressor(kind, &cfg, None);
            r.fit(&ds);
            assert_eq!(r.n(), 20, "{}", r.name());
            let (coefs, _, b) = r.coefficients(ds.row(0));
            assert_eq!(coefs.len(), 20, "{}", r.name());
            assert!(b.is_finite());
        }
    }

    #[test]
    fn deployment_spec_resolves_both_families() {
        use crate::data::{make_regression, RegressionSpec};
        let cls = make_classification(
            &ClassificationSpec {
                n_samples: 24,
                ..Default::default()
            },
            1,
        );
        let reg = make_regression(
            &RegressionSpec {
                n_samples: 20,
                n_features: 4,
                n_informative: 3,
                noise: 2.0,
            },
            3,
        );
        let spec = DeploymentSpec {
            name: "knn-a".into(),
            kind: "simplified-knn".into(),
            measure: MeasureConfig {
                k: 3,
                ..Default::default()
            },
        };
        let d = deployment_from_spec(&spec, &cls, &reg, None).unwrap();
        assert!(!d.is_regression());
        let spec = DeploymentSpec {
            name: "rrcm".into(),
            kind: "ridge".into(),
            measure: MeasureConfig {
                rho: 0.7,
                ..Default::default()
            },
        };
        let d = deployment_from_spec(&spec, &cls, &reg, None).unwrap();
        assert!(d.is_regression());
        let bad = DeploymentSpec {
            name: "x".into(),
            kind: "bogus".into(),
            measure: MeasureConfig::default(),
        };
        assert!(deployment_from_spec(&bad, &cls, &reg, None).is_err());
    }

    #[test]
    fn select_engine_falls_back() {
        let eng = select_engine(true, "/nonexistent/artifacts", 1);
        assert_eq!(eng.name(), "native");
    }

    #[test]
    fn select_engine_threads_native_path() {
        let eng = select_engine(false, "artifacts", 4);
        assert_eq!(eng.name(), "native-threaded");
    }
}
