//! Dynamic batcher: a bounded MPMC queue whose consumers drain up to
//! `max_batch` items, waiting at most `max_wait` for stragglers once the
//! first item arrives — the standard serving trade-off between batching
//! efficiency and tail latency. Backpressure: `push` fails fast when the
//! queue is full, so the TCP front end can shed load instead of queueing
//! unboundedly.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Bounded batch queue.
pub struct Batcher<T> {
    q: Mutex<State<T>>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Why `push` failed.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError {
    /// queue at capacity (backpressure — shed load)
    Full,
    /// batcher shut down
    Closed,
}

/// One drained batch plus assembly observability (consumed by the
/// worker pool to feed batch-assembly spans and the queue-depth gauge).
pub struct Drain<T> {
    pub items: Vec<T>,
    /// when the first item of this batch was observed (assembly start)
    pub started: Instant,
    /// time spent assembling: first item observed -> batch handed over
    pub assembled: Duration,
    /// items still queued right after this drain (queue-depth gauge)
    pub depth_after: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration, capacity: usize) -> Self {
        assert!(max_batch >= 1);
        Batcher {
            q: Mutex::new(State {
                items: VecDeque::with_capacity(capacity.min(4096)),
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch,
            max_wait,
            capacity,
        }
    }

    /// Enqueue one item (non-blocking; backpressure via `PushError::Full`).
    pub fn push(&self, item: T) -> Result<(), PushError> {
        // LOCK-ORDER: batcher.queue — innermost lock on the producer
        // side; held only for the push, dropped before notify.
        let mut st = self.q.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed);
        }
        if st.items.len() >= self.capacity {
            return Err(PushError::Full);
        }
        st.items.push_back(item);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Block until at least one item is available (or closed), then
    /// drain up to `max_batch`, waiting `max_wait` for the batch to fill.
    /// Returns None when closed and drained.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        self.next_batch_stats().map(|d| d.items)
    }

    /// [`Batcher::next_batch`] plus assembly stats — same drain
    /// semantics (model-checked through `next_batch` in
    /// `tests/batcher_schedules.rs`), additionally reporting when
    /// assembly started, how long it took, and the queue depth right
    /// after the drain.
    pub fn next_batch_stats(&self) -> Option<Drain<T>> {
        // LOCK-ORDER: batcher.queue — consumer drain; no other lock is
        // ever taken while this one is held.
        let mut st = self.q.lock().unwrap();
        // wait for the first item
        while st.items.is_empty() {
            if st.closed {
                return None;
            }
            // LOCK-ORDER: batcher.queue — condvar wait reacquires it.
            st = self.cv.wait(st).unwrap();
        }
        // give stragglers a chance to fill the batch
        let started = Instant::now();
        let deadline = started + self.max_wait;
        while st.items.len() < self.max_batch && !st.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            // LOCK-ORDER: batcher.queue — timed condvar wait reacquires.
            let (g, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = g;
            if timeout.timed_out() {
                break;
            }
        }
        let take = st.items.len().min(self.max_batch);
        let items: Vec<T> = st.items.drain(..take).collect();
        let depth_after = st.items.len();
        Some(Drain {
            items,
            started,
            assembled: started.elapsed(),
            depth_after,
        })
    }

    /// Current depth (diagnostics).
    pub fn depth(&self) -> usize {
        // LOCK-ORDER: batcher.queue — read-only peek for metrics.
        self.q.lock().unwrap().items.len()
    }

    /// Shut down: wakes all consumers; subsequent pushes fail.
    pub fn close(&self) {
        // LOCK-ORDER: batcher.queue — flag flip, then broadcast.
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn batches_up_to_max() {
        let b = Batcher::new(3, Duration::from_millis(5), 100);
        for i in 0..7 {
            b.push(i).unwrap();
        }
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2]);
        assert_eq!(b.next_batch().unwrap(), vec![3, 4, 5]);
        assert_eq!(b.next_batch().unwrap(), vec![6]);
    }

    #[test]
    fn backpressure_when_full() {
        let b = Batcher::new(4, Duration::from_millis(1), 2);
        b.push(1).unwrap();
        b.push(2).unwrap();
        assert_eq!(b.push(3), Err(PushError::Full));
    }

    #[test]
    fn close_drains_and_stops() {
        let b = Batcher::new(4, Duration::from_millis(1), 10);
        b.push(1).unwrap();
        b.close();
        assert_eq!(b.push(2), Err(PushError::Closed));
        assert_eq!(b.next_batch().unwrap(), vec![1]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn consumer_wakes_on_late_producer() {
        let b = Arc::new(Batcher::new(8, Duration::from_millis(2), 100));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.push(42).unwrap();
        let got = h.join().unwrap().unwrap();
        assert_eq!(got, vec![42]);
    }

    #[test]
    fn straggler_window_fills_batch() {
        let b = Arc::new(Batcher::new(2, Duration::from_millis(200), 100));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        b.push(1).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        b.push(2).unwrap();
        let got = h.join().unwrap().unwrap();
        assert_eq!(got, vec![1, 2], "straggler should join the batch");
    }

    #[test]
    fn max_wait_cutoff_ships_partial_batch() {
        // an under-full batch must ship once max_wait expires, NOT wait
        // for items that arrive after the deadline
        let b = Arc::new(Batcher::new(8, Duration::from_millis(40), 100));
        b.push(1).unwrap();
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            let t0 = Instant::now();
            (b2.next_batch(), t0.elapsed())
        });
        // a very late straggler, far past the deadline
        std::thread::sleep(Duration::from_millis(300));
        b.push(2).unwrap();
        let (got, waited) = h.join().unwrap();
        assert_eq!(got.unwrap(), vec![1], "late item must miss the batch");
        assert!(
            waited < Duration::from_millis(250),
            "cutoff ignored: waited {waited:?}"
        );
        // the late item is still queued for the next batch
        assert_eq!(b.next_batch().unwrap(), vec![2]);
    }

    #[test]
    fn full_backpressure_recovers_after_drain() {
        let b = Batcher::new(4, Duration::from_millis(1), 2);
        b.push(1).unwrap();
        b.push(2).unwrap();
        assert_eq!(b.push(3), Err(PushError::Full));
        assert_eq!(b.depth(), 2, "rejected push must not corrupt queue");
        assert_eq!(b.next_batch().unwrap(), vec![1, 2]);
        // capacity freed: pushes succeed again
        b.push(3).unwrap();
        assert_eq!(b.depth(), 1);
        assert_eq!(b.next_batch().unwrap(), vec![3]);
    }

    #[test]
    fn close_drains_in_max_batch_chunks_then_none() {
        let b = Batcher::new(2, Duration::from_millis(1), 10);
        for i in 0..5 {
            b.push(i).unwrap();
        }
        b.close();
        assert_eq!(b.push(9), Err(PushError::Closed));
        // drain respects max_batch even after close
        assert_eq!(b.next_batch().unwrap(), vec![0, 1]);
        assert_eq!(b.next_batch().unwrap(), vec![2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4]);
        assert!(b.next_batch().is_none());
        assert!(b.next_batch().is_none(), "closed state is terminal");
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn next_batch_stats_reports_depth_and_assembly() {
        let b = Batcher::new(2, Duration::from_millis(1), 10);
        for i in 0..5 {
            b.push(i).unwrap();
        }
        let d = b.next_batch_stats().unwrap();
        assert_eq!(d.items, vec![0, 1]);
        assert_eq!(d.depth_after, 3, "gauge sees what is still queued");
        assert!(d.assembled >= Duration::ZERO);
        // delegation: next_batch sees the same stream
        assert_eq!(b.next_batch().unwrap(), vec![2, 3]);
        assert_eq!(b.next_batch_stats().unwrap().items, vec![4]);
        b.close();
        assert!(b.next_batch_stats().is_none());
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let b = Arc::new(Batcher::new(4, Duration::from_millis(1), 10));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none(), "consumer must wake on close");
    }

    #[test]
    fn close_during_straggler_wait_ships_immediately() {
        // consumer holds one item inside the straggler window; close()
        // must cut the wait short and ship what it has
        let b = Arc::new(Batcher::new(8, Duration::from_secs(5), 10));
        b.push(7).unwrap();
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            let t0 = Instant::now();
            (b2.next_batch(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(30));
        b.close();
        let (got, waited) = h.join().unwrap();
        assert_eq!(got.unwrap(), vec![7]);
        assert!(
            waited < Duration::from_secs(4),
            "close ignored mid-wait: {waited:?}"
        );
    }
}
