//! Deployment registry: named, independently-trained CP instances with
//! online learn/unlearn — the coordinator's state-management layer.

use std::collections::HashMap;
use std::sync::RwLock;

use anyhow::{anyhow, bail, Result};

use crate::config::{MeasureConfig, MeasureKind};
use crate::coordinator::factory::build_measure;
use crate::cp::measure::CpMeasure;
use crate::cp::pvalue::p_value;
use crate::data::{Dataset, Label};
use crate::linalg::engine::Engine;

/// One deployed conformal predictor.
pub struct Deployment {
    pub name: String,
    pub kind: MeasureKind,
    measure: Box<dyn CpMeasure>,
    n_labels: usize,
    /// monotone version, bumped by online updates
    pub version: u64,
}

impl Deployment {
    pub fn train(
        name: &str,
        kind: MeasureKind,
        cfg: &MeasureConfig,
        ds: &Dataset,
        engine: Option<Engine>,
    ) -> Self {
        let mut measure = build_measure(kind, cfg, engine);
        measure.fit(ds);
        Deployment {
            name: name.to_string(),
            kind,
            measure,
            n_labels: ds.n_labels,
            version: 0,
        }
    }

    pub fn p_values(&self, x: &[f64]) -> Vec<f64> {
        (0..self.n_labels)
            .map(|y| p_value(&self.measure.scores(x, y)))
            .collect()
    }

    /// Per-label p-values for a whole batch of test objects through ONE
    /// [`CpMeasure::scores_batch`] call — the serving hot path: the
    /// worker pool drains a dynamic batch and scores it here so each
    /// object's distance/kernel row is computed once, not once per
    /// label. Row i corresponds to `xs[i]`; output equals per-object
    /// [`Deployment::p_values`] bit for bit (the measure's batch
    /// contract).
    pub fn p_values_batch(&self, xs: &[&[f64]]) -> Vec<Vec<f64>> {
        crate::cp::pvalue::p_value_rows(
            self.measure.as_ref(),
            xs,
            self.n_labels,
        )
    }

    pub fn predict_set(&self, x: &[f64], eps: f64) -> Vec<Label> {
        crate::cp::classifier::set_from_p_values(&self.p_values(x), eps)
    }

    /// Online increment; Err if the measure cannot update in place.
    pub fn learn(&mut self, x: &[f64], y: Label) -> Result<()> {
        if self.measure.learn(x, y) {
            self.version += 1;
            Ok(())
        } else {
            bail!("measure {} does not support online learn", self.measure.name())
        }
    }

    /// Online decrement by training index.
    pub fn unlearn(&mut self, idx: usize) -> Result<()> {
        if self.measure.unlearn(idx) {
            self.version += 1;
            Ok(())
        } else {
            bail!("measure {} does not support online unlearn", self.measure.name())
        }
    }

    pub fn n_train(&self) -> usize {
        self.measure.n()
    }

    pub fn measure_name(&self) -> String {
        self.measure.name()
    }
}

/// Thread-safe registry of deployments.
#[derive(Default)]
pub struct Registry {
    inner: RwLock<HashMap<String, Deployment>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&self, d: Deployment) {
        self.inner.write().unwrap().insert(d.name.clone(), d);
    }

    pub fn remove(&self, name: &str) -> bool {
        self.inner.write().unwrap().remove(name).is_some()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.inner.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Run `f` against a deployment under the read lock.
    pub fn with<R>(
        &self,
        name: &str,
        f: impl FnOnce(&Deployment) -> R,
    ) -> Result<R> {
        let guard = self.inner.read().unwrap();
        let d = guard
            .get(name)
            .ok_or_else(|| anyhow!("unknown deployment {name:?}"))?;
        Ok(f(d))
    }

    /// Run `f` against a deployment under the write lock (online updates).
    pub fn with_mut<R>(
        &self,
        name: &str,
        f: impl FnOnce(&mut Deployment) -> R,
    ) -> Result<R> {
        let mut guard = self.inner.write().unwrap();
        let d = guard
            .get_mut(name)
            .ok_or_else(|| anyhow!("unknown deployment {name:?}"))?;
        Ok(f(d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_classification, ClassificationSpec};

    fn ds(n: usize, seed: u64) -> Dataset {
        make_classification(
            &ClassificationSpec {
                n_samples: n,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn deployment_predicts_and_updates() {
        let d = ds(40, 1);
        let mut dep = Deployment::train(
            "knn",
            MeasureKind::SimplifiedKnn,
            &MeasureConfig {
                k: 3,
                ..Default::default()
            },
            &d,
            None,
        );
        let ps = dep.p_values(d.row(0));
        assert_eq!(ps.len(), 2);
        assert_eq!(dep.n_train(), 40);
        dep.learn(&vec![0.0; 30], 1).unwrap();
        assert_eq!(dep.n_train(), 41);
        assert_eq!(dep.version, 1);
        dep.unlearn(40).unwrap();
        assert_eq!(dep.n_train(), 40);
    }

    #[test]
    fn p_values_batch_matches_single() {
        let d = ds(30, 3);
        let dep = Deployment::train(
            "kde",
            MeasureKind::Kde,
            &MeasureConfig::default(),
            &d,
            None,
        );
        let xs: Vec<&[f64]> = (0..4).map(|i| d.row(i)).collect();
        let rows = dep.p_values_batch(&xs);
        assert_eq!(rows.len(), 4);
        for (x, row) in xs.iter().zip(&rows) {
            assert_eq!(row, &dep.p_values(x));
        }
        assert!(dep.p_values_batch(&[]).is_empty());
    }

    #[test]
    fn registry_routing() {
        let reg = Registry::new();
        let d = ds(20, 2);
        let cfg = MeasureConfig {
            k: 3,
            ..Default::default()
        };
        reg.insert(Deployment::train(
            "a",
            MeasureKind::SimplifiedKnn,
            &cfg,
            &d,
            None,
        ));
        reg.insert(Deployment::train("b", MeasureKind::Kde, &cfg, &d, None));
        assert_eq!(reg.names(), vec!["a", "b"]);
        let n = reg.with("a", |dep| dep.n_train()).unwrap();
        assert_eq!(n, 20);
        assert!(reg.with("missing", |_| ()).is_err());
        assert!(reg.remove("b"));
        assert_eq!(reg.names(), vec!["a"]);
    }
}
