//! Deployment registry: named, independently-trained CP instances with
//! online learn/unlearn — the coordinator's state-management layer.

use std::collections::HashMap;
use std::sync::RwLock;

use anyhow::{anyhow, bail, Result};

use crate::config::{MeasureConfig, MeasureKind, RegressorKind};
use crate::coordinator::factory::{build_measure, build_regressor};
use crate::cp::measure::CpMeasure;
use crate::cp::pvalue::p_value;
use crate::data::{Dataset, Label, RegressionDataset};
use crate::linalg::engine::Engine;
use crate::regression::{conformal_region, p_value_at, CpRegressor, Region};

/// What a deployment serves: label p-values or regression intervals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeploymentKind {
    Classifier(MeasureKind),
    Regressor(RegressorKind),
}

impl DeploymentKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            DeploymentKind::Classifier(k) => k.as_str(),
            DeploymentKind::Regressor(k) => k.as_str(),
        }
    }
}

/// The trained model behind a deployment.
enum Model {
    Classifier {
        measure: Box<dyn CpMeasure>,
        n_labels: usize,
    },
    Regressor {
        regressor: Box<dyn CpRegressor>,
    },
}

/// One batched regression answer: the exact prediction region plus,
/// when the request supplied a candidate `y`, its conformal p-value
/// (computed from the same coefficient sweep, so it is consistent with
/// the region by construction).
pub struct RegionAnswer {
    pub region: Region,
    pub p_at_y: Option<f64>,
}

/// One deployed conformal predictor.
pub struct Deployment {
    pub name: String,
    pub kind: DeploymentKind,
    model: Model,
    /// monotone version, bumped by online updates
    pub version: u64,
}

impl Deployment {
    pub fn train(
        name: &str,
        kind: MeasureKind,
        cfg: &MeasureConfig,
        ds: &Dataset,
        engine: Option<Engine>,
    ) -> Self {
        let mut measure = build_measure(kind, cfg, engine);
        measure.fit(ds);
        Deployment {
            name: name.to_string(),
            kind: DeploymentKind::Classifier(kind),
            model: Model::Classifier {
                measure,
                n_labels: ds.n_labels,
            },
            version: 0,
        }
    }

    /// Train a regression deployment (served via `op: "predict_region"`).
    pub fn train_regression(
        name: &str,
        kind: RegressorKind,
        cfg: &MeasureConfig,
        ds: &RegressionDataset,
        engine: Option<Engine>,
    ) -> Self {
        let mut regressor = build_regressor(kind, cfg, engine);
        regressor.fit(ds);
        Deployment {
            name: name.to_string(),
            kind: DeploymentKind::Regressor(kind),
            model: Model::Regressor { regressor },
            version: 0,
        }
    }

    pub fn is_regression(&self) -> bool {
        matches!(self.model, Model::Regressor { .. })
    }

    fn classifier(&self) -> (&dyn CpMeasure, usize) {
        match &self.model {
            Model::Classifier { measure, n_labels } => {
                (measure.as_ref(), *n_labels)
            }
            Model::Regressor { .. } => panic!(
                "deployment {:?} is a regression deployment; \
                 callers must route via region_rows",
                self.name
            ),
        }
    }

    pub fn p_values(&self, x: &[f64]) -> Vec<f64> {
        let (measure, n_labels) = self.classifier();
        (0..n_labels).map(|y| p_value(&measure.scores(x, y))).collect()
    }

    /// Per-label p-values for a whole batch of test objects through ONE
    /// [`CpMeasure::scores_batch`] call — the serving hot path: the
    /// worker pool drains a dynamic batch and scores it here so each
    /// object's distance/kernel row is computed once, not once per
    /// label. Row i corresponds to `xs[i]`; output equals per-object
    /// [`Deployment::p_values`] bit for bit (the measure's batch
    /// contract).
    pub fn p_values_batch(&self, xs: &[&[f64]]) -> Vec<Vec<f64>> {
        let (measure, n_labels) = self.classifier();
        crate::cp::pvalue::p_value_rows(measure, xs, n_labels)
    }

    pub fn predict_set(&self, x: &[f64], eps: f64) -> Vec<Label> {
        crate::cp::classifier::set_from_p_values(&self.p_values(x), eps)
    }

    /// Batched regression answers — the regression serving hot path,
    /// mirroring [`Deployment::p_values_batch`]: ONE
    /// [`CpRegressor::coefficients_batch`] call per chunk, then a
    /// per-object sweep. `eps` and the optional candidate label may
    /// differ per object because only the sweep depends on them, never
    /// the coefficients. Errors if this is a classification deployment.
    pub fn region_rows(
        &self,
        xs: &[&[f64]],
        eps: &[f64],
        ys: &[Option<f64>],
    ) -> Result<Vec<RegionAnswer>> {
        let Model::Regressor { regressor } = &self.model else {
            bail!(
                "deployment {:?} is a classification deployment \
                 (use op \"predict\")",
                self.name
            );
        };
        assert_eq!(xs.len(), eps.len());
        assert_eq!(xs.len(), ys.len());
        let _span = crate::obs::trace::span_args(
            crate::obs::Stage::RegionSweep,
            [xs.len() as u64, 0, 0, 0],
        );
        Ok(regressor
            .coefficients_batch(xs)
            .into_iter()
            .zip(eps.iter().zip(ys))
            .map(|((coefs, a, b), (&e, &y))| RegionAnswer {
                region: conformal_region(&coefs, a, b, e),
                p_at_y: y.map(|y| p_value_at(&coefs, a, b, y)),
            })
            .collect())
    }

    /// Single-object regression answer; equals `region_rows` on a
    /// singleton batch (same coefficients, same sweep).
    pub fn predict_region(
        &self,
        x: &[f64],
        eps: f64,
        y: Option<f64>,
    ) -> Result<RegionAnswer> {
        Ok(self
            .region_rows(&[x], &[eps], &[y])?
            .pop()
            .expect("one answer for one object"))
    }

    /// Online increment; Err if the measure cannot update in place.
    pub fn learn(&mut self, x: &[f64], y: Label) -> Result<()> {
        let Model::Classifier { measure, .. } = &mut self.model else {
            bail!(
                "deployment {:?} is a regression deployment; \
                 y must be a float label",
                self.name
            );
        };
        if measure.learn(x, y) {
            self.version += 1;
            Ok(())
        } else {
            bail!("measure {} does not support online learn", measure.name())
        }
    }

    /// Online increment for regression deployments (float label).
    pub fn learn_reg(&mut self, x: &[f64], y: f64) -> Result<()> {
        let Model::Regressor { regressor } = &mut self.model else {
            bail!(
                "deployment {:?} is a classification deployment; \
                 y must be an integer label",
                self.name
            );
        };
        if regressor.learn(x, y) {
            self.version += 1;
            Ok(())
        } else {
            bail!(
                "regressor {} does not support online learn",
                regressor.name()
            )
        }
    }

    /// Online decrement by training index — classification and
    /// regression deployments alike. Out-of-range indexes are reported
    /// distinctly from missing decremental support so clients can tell
    /// a bad request from a capability gap.
    pub fn unlearn(&mut self, idx: usize) -> Result<()> {
        let n = self.n_train();
        if idx >= n {
            bail!(
                "unlearn index {} out of range for deployment {:?} \
                 (n_train = {})",
                idx,
                self.name,
                n
            );
        }
        let (ok, name) = match &mut self.model {
            Model::Classifier { measure, .. } => {
                (measure.unlearn(idx), measure.name())
            }
            Model::Regressor { regressor } => {
                (regressor.unlearn(idx), regressor.name())
            }
        };
        if ok {
            self.version += 1;
            Ok(())
        } else {
            bail!("model {name} does not support online unlearn")
        }
    }

    pub fn n_train(&self) -> usize {
        match &self.model {
            Model::Classifier { measure, .. } => measure.n(),
            Model::Regressor { regressor } => regressor.n(),
        }
    }

    pub fn measure_name(&self) -> String {
        match &self.model {
            Model::Classifier { measure, .. } => measure.name(),
            Model::Regressor { regressor } => regressor.name(),
        }
    }
}

/// Thread-safe registry of deployments.
#[derive(Default)]
pub struct Registry {
    inner: RwLock<HashMap<String, Deployment>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&self, d: Deployment) {
        // LOCK-ORDER: coordinator.registry — exclusive insert.
        self.inner.write().unwrap().insert(d.name.clone(), d);
    }

    pub fn remove(&self, name: &str) -> bool {
        // LOCK-ORDER: coordinator.registry — exclusive remove.
        self.inner.write().unwrap().remove(name).is_some()
    }

    pub fn names(&self) -> Vec<String> {
        // LOCK-ORDER: coordinator.registry — shared listing.
        let mut v: Vec<String> =
            self.inner.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Run `f` against a deployment under the read lock.
    pub fn with<R>(
        &self,
        name: &str,
        f: impl FnOnce(&Deployment) -> R,
    ) -> Result<R> {
        // LOCK-ORDER: coordinator.registry — outermost lock; `f` runs
        // scoring under it and may take runtime.exec_cache /
        // linalg.tile_queue, both ranked below it.
        let guard = self.inner.read().unwrap();
        let d = guard
            .get(name)
            .ok_or_else(|| anyhow!("unknown deployment {name:?}"))?;
        Ok(f(d))
    }

    /// Run `f` against a deployment under the write lock (online updates).
    pub fn with_mut<R>(
        &self,
        name: &str,
        f: impl FnOnce(&mut Deployment) -> R,
    ) -> Result<R> {
        // LOCK-ORDER: coordinator.registry — outermost lock, exclusive
        // for online insert/delete updates; same inner-lock rule as
        // `with`.
        let mut guard = self.inner.write().unwrap();
        let d = guard
            .get_mut(name)
            .ok_or_else(|| anyhow!("unknown deployment {name:?}"))?;
        Ok(f(d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_classification, ClassificationSpec};

    fn ds(n: usize, seed: u64) -> Dataset {
        make_classification(
            &ClassificationSpec {
                n_samples: n,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn deployment_predicts_and_updates() {
        let d = ds(40, 1);
        let mut dep = Deployment::train(
            "knn",
            MeasureKind::SimplifiedKnn,
            &MeasureConfig {
                k: 3,
                ..Default::default()
            },
            &d,
            None,
        );
        let ps = dep.p_values(d.row(0));
        assert_eq!(ps.len(), 2);
        assert_eq!(dep.n_train(), 40);
        dep.learn(&vec![0.0; 30], 1).unwrap();
        assert_eq!(dep.n_train(), 41);
        assert_eq!(dep.version, 1);
        dep.unlearn(40).unwrap();
        assert_eq!(dep.n_train(), 40);
    }

    #[test]
    fn p_values_batch_matches_single() {
        let d = ds(30, 3);
        let dep = Deployment::train(
            "kde",
            MeasureKind::Kde,
            &MeasureConfig::default(),
            &d,
            None,
        );
        let xs: Vec<&[f64]> = (0..4).map(|i| d.row(i)).collect();
        let rows = dep.p_values_batch(&xs);
        assert_eq!(rows.len(), 4);
        for (x, row) in xs.iter().zip(&rows) {
            assert_eq!(row, &dep.p_values(x));
        }
        assert!(dep.p_values_batch(&[]).is_empty());
    }

    #[test]
    fn regression_deployment_round_trip() {
        use crate::data::{make_regression, RegressionSpec};
        let rds = make_regression(
            &RegressionSpec {
                n_samples: 30,
                n_features: 4,
                n_informative: 3,
                noise: 3.0,
            },
            5,
        );
        let cfg = MeasureConfig {
            k: 3,
            ..Default::default()
        };
        let mut dep = Deployment::train_regression(
            "reg",
            RegressorKind::Knn,
            &cfg,
            &rds,
            None,
        );
        assert!(dep.is_regression());
        assert_eq!(dep.n_train(), 30);
        // batched answers equal singles exactly, per-object eps honoured
        let xs: Vec<&[f64]> = (0..3).map(|i| rds.row(i)).collect();
        let eps = [0.1, 0.3, 0.1];
        let ys = [Some(rds.y[0]), None, Some(-1e6)];
        let rows = dep.region_rows(&xs, &eps, &ys).unwrap();
        assert_eq!(rows.len(), 3);
        for (i, row) in rows.iter().enumerate() {
            let single = dep.predict_region(xs[i], eps[i], ys[i]).unwrap();
            assert_eq!(row.region, single.region, "i={i}");
            assert_eq!(row.p_at_y, single.p_at_y, "i={i}");
        }
        assert!(rows[0].p_at_y.unwrap() > 0.0);
        assert!(rows[1].p_at_y.is_none());
        // a label a million units away must be maximally nonconforming
        assert!(rows[2].p_at_y.unwrap() <= 2.0 / 31.0 + 1e-12);
        // wrong-op routing errors instead of panicking
        assert!(dep.learn(&vec![0.0; 4], 1).is_err());
        // out-of-range unlearn is a structured error; in-range works
        // and bumps the version (decremental regression serving)
        assert!(dep.unlearn(30).is_err());
        dep.unlearn(29).unwrap();
        assert_eq!(dep.n_train(), 29);
        assert_eq!(dep.version, 1);
        // float-label learn works and bumps the version
        dep.learn_reg(rds.row(0), rds.y[0]).unwrap();
        assert_eq!(dep.n_train(), 30);
        assert_eq!(dep.version, 2);
        // classifiers reject float-label learn symmetrically
        let cds = ds(20, 6);
        let mut cdep = Deployment::train(
            "cls",
            MeasureKind::SimplifiedKnn,
            &cfg,
            &cds,
            None,
        );
        assert!(cdep.learn_reg(cds.row(0), 0.5).is_err());
        assert!(cdep.region_rows(&[cds.row(0)], &[0.1], &[None]).is_err());
    }

    #[test]
    fn regression_unlearn_matches_fresh_deployment() {
        // every served regressor kind: unlearn then predict_region
        // answers equal a deployment freshly trained on the reduced set
        use crate::data::{make_regression, RegressionSpec};
        let rds = make_regression(
            &RegressionSpec {
                n_samples: 30,
                n_features: 4,
                n_informative: 3,
                noise: 3.0,
            },
            7,
        );
        let cfg = MeasureConfig {
            k: 3,
            ..Default::default()
        };
        for kind in RegressorKind::all() {
            let mut dep =
                Deployment::train_regression("d", kind, &cfg, &rds, None);
            dep.unlearn(12).unwrap();
            dep.unlearn(0).unwrap();
            assert_eq!(dep.version, 2);
            let mut reduced = rds.clone();
            reduced.remove(12);
            reduced.remove(0);
            let fresh =
                Deployment::train_regression("d2", kind, &cfg, &reduced, None);
            assert_eq!(dep.n_train(), fresh.n_train());
            for i in 0..3 {
                let y = Some(rds.y[i]);
                let a = dep.predict_region(rds.row(i), 0.1, y).unwrap();
                let b = fresh.predict_region(rds.row(i), 0.1, y).unwrap();
                assert_eq!(a.region, b.region, "{kind:?} i={i}");
                assert_eq!(a.p_at_y, b.p_at_y, "{kind:?} i={i}");
            }
        }
    }

    #[test]
    fn registry_routing() {
        let reg = Registry::new();
        let d = ds(20, 2);
        let cfg = MeasureConfig {
            k: 3,
            ..Default::default()
        };
        reg.insert(Deployment::train(
            "a",
            MeasureKind::SimplifiedKnn,
            &cfg,
            &d,
            None,
        ));
        reg.insert(Deployment::train("b", MeasureKind::Kde, &cfg, &d, None));
        assert_eq!(reg.names(), vec!["a", "b"]);
        let n = reg.with("a", |dep| dep.n_train()).unwrap();
        assert_eq!(n, 20);
        assert!(reg.with("missing", |_| ()).is_err());
        assert!(reg.remove("b"));
        assert_eq!(reg.names(), vec!["a"]);
    }
}
