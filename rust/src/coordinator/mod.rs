//! L3 serving coordinator.
//!
//! A deployable conformal-prediction service around the optimized
//! measures: a TCP JSON-lines server with a *dynamic batcher*, a worker
//! pool, per-deployment state with online **learn/unlearn** (the
//! incremental&decremental capability is what makes online serving
//! cheap — §9's online-learning discussion), backpressure, and metrics.
//!
//! - [`factory`]  — build measures from [`crate::config::MeasureKind`]
//!   and resolve `[serve.deployment.X]` spec blocks;
//! - [`state`]    — deployment registry (trained CP per measure);
//! - [`batcher`]  — bounded queue + deadline-based batch draining;
//! - [`metrics`]  — process-wide counters and latency histograms
//!   (per-deployment × per-op blocks live in [`crate::obs::metrics`]);
//! - [`server`]   — the TCP front end and worker loop, threaded with
//!   [`crate::obs`] stage spans, per-deployment metrics, and online
//!   validity monitoring (wire reference: PROTOCOL.md).

pub mod batcher;
pub mod factory;
pub mod metrics;
pub mod server;
pub mod state;

pub use batcher::Batcher;
pub use factory::build_measure;
pub use metrics::Metrics;
pub use server::{serve, Server};
pub use state::{Deployment, Registry};
