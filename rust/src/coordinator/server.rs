//! The serving front end: JSON-lines over TCP, dynamic batching, worker
//! pool, online updates, metrics.
//!
//! Protocol (one JSON object per line, response mirrors request `id`):
//!
//! ```text
//! -> {"op":"predict","deployment":"knn","x":[...],"epsilon":0.1,"id":1}
//! <- {"id":1,"p_values":[0.8,0.05],"set":[0],"forced":0}
//! -> {"op":"learn","deployment":"knn","x":[...],"y":1}
//! <- {"ok":true,"n_train":101,"version":1}
//! -> {"op":"unlearn","deployment":"knn","index":17}
//! -> {"op":"stats"} | {"op":"list"} | {"op":"ping"} | {"op":"shutdown"}
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServeConfig;
use crate::coordinator::batcher::{Batcher, PushError};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::state::Registry;
use crate::cp::classifier::{forced_from_p_values, set_from_p_values};
use crate::util::json::Json;

/// One queued prediction job.
struct Job {
    deployment: String,
    x: Vec<f64>,
    eps: f64,
    enqueued: Instant,
    resp: mpsc::Sender<Json>,
}

/// The coordinator server: registry + batcher + workers + metrics.
pub struct Server {
    pub registry: Arc<Registry>,
    pub metrics: Arc<Metrics>,
    batcher: Arc<Batcher<Job>>,
    cfg: ServeConfig,
    workers: Vec<std::thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Start the worker pool (does not bind the socket — see [`serve`]).
    pub fn start(cfg: ServeConfig, registry: Arc<Registry>) -> Server {
        let metrics = Arc::new(Metrics::new());
        let batcher = Arc::new(Batcher::new(
            cfg.max_batch,
            Duration::from_micros(cfg.max_wait_us),
            cfg.queue_depth,
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let b = batcher.clone();
                let reg = registry.clone();
                let met = metrics.clone();
                std::thread::spawn(move || {
                    while let Some(batch) = b.next_batch() {
                        met.record_batch(batch.len());
                        Self::run_batch(&reg, &met, batch);
                    }
                })
            })
            .collect();
        Server {
            registry,
            metrics,
            batcher,
            cfg,
            workers,
            stop,
        }
    }

    /// Score one drained batch. Jobs are grouped by deployment
    /// (preserving arrival order within each group) and scored with one
    /// `Deployment::p_values_batch` call per `LOCK_CHUNK`-job sub-chunk,
    /// so each test object's distance/kernel row is computed once
    /// rather than once per candidate label — the batch axis the
    /// dynamic batcher exists to exploit. Workers each drain their own
    /// batch, so the existing pool still fans chunks out across cores.
    fn run_batch(reg: &Registry, met: &Metrics, batch: Vec<Job>) {
        let mut groups: Vec<(String, Vec<Job>)> = Vec::new();
        for job in batch {
            match groups.iter_mut().find(|(d, _)| *d == job.deployment) {
                Some((_, jobs)) => jobs.push(job),
                None => {
                    let dep = job.deployment.clone();
                    groups.push((dep, vec![job]));
                }
            }
        }
        // Lock-hold bound: the read lock is reacquired per sub-chunk so
        // a pending learn/unlearn (write lock) waits for at most one
        // chunk, not a whole group — the old per-job path released the
        // lock between jobs; this is the same fairness at 1/CHUNK the
        // acquisitions. Within a chunk each object's row reuse across
        // labels (the main batch win) is fully preserved.
        const LOCK_CHUNK: usize = 16;
        for (dep, jobs) in groups {
            for chunk in jobs.chunks(LOCK_CHUNK) {
                let xs: Vec<&[f64]> =
                    chunk.iter().map(|j| j.x.as_slice()).collect();
                match reg.with(&dep, |d| d.p_values_batch(&xs)) {
                    Ok(ps_rows) => {
                        debug_assert_eq!(ps_rows.len(), chunk.len());
                        for (job, ps) in chunk.iter().zip(ps_rows) {
                            let out = predict_json(&ps, job.eps);
                            met.observe_latency_us(
                                job.enqueued.elapsed().as_micros() as u64,
                            );
                            met.predictions.fetch_add(1, Ordering::Relaxed);
                            let _ = job.resp.send(out);
                        }
                    }
                    Err(e) => {
                        // metrics parity with the success arm (and the
                        // old per-job loop): failed jobs still count as
                        // served predictions and contribute latency
                        let msg = e.to_string();
                        for job in chunk {
                            met.observe_latency_us(
                                job.enqueued.elapsed().as_micros() as u64,
                            );
                            met.predictions.fetch_add(1, Ordering::Relaxed);
                            let _ = job.resp.send(err_json(&msg));
                        }
                    }
                }
            }
        }
    }

    /// Handle one request object (in-process entry point; the TCP layer
    /// and the tests both go through here).
    pub fn handle(&self, req: &Json) -> Json {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let id = req.get("id").cloned().unwrap_or(Json::Null);
        let mut out = match req.get("op").and_then(Json::as_str) {
            Some("predict") => self.handle_predict(req),
            Some("learn") => self.handle_learn(req),
            Some("unlearn") => self.handle_unlearn(req),
            Some("stats") => self.metrics.snapshot(),
            Some("list") => Json::obj(vec![(
                "deployments",
                Json::Arr(
                    self.registry
                        .names()
                        .into_iter()
                        .map(Json::Str)
                        .collect(),
                ),
            )]),
            Some("ping") => Json::obj(vec![("ok", Json::Bool(true))]),
            Some("shutdown") => {
                self.stop.store(true, Ordering::SeqCst);
                Json::obj(vec![("ok", Json::Bool(true))])
            }
            _ => err_json("unknown or missing op"),
        };
        if let Json::Obj(m) = &mut out {
            m.insert("id".into(), id);
        }
        out
    }

    fn handle_predict(&self, req: &Json) -> Json {
        let Some(dep) = req.get("deployment").and_then(Json::as_str) else {
            return err_json("missing deployment");
        };
        let Some(x) = req.get("x").and_then(Json::as_f64_vec) else {
            return err_json("missing x");
        };
        let eps = req
            .get("epsilon")
            .and_then(Json::as_f64)
            .unwrap_or(self.cfg.default_epsilon);
        let (tx, rx) = mpsc::channel();
        let job = Job {
            deployment: dep.to_string(),
            x,
            eps,
            enqueued: Instant::now(),
            resp: tx,
        };
        match self.batcher.push(job) {
            Ok(()) => match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(j) => j,
                Err(_) => {
                    self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    err_json("prediction timed out")
                }
            },
            Err(PushError::Full) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                err_json("overloaded (backpressure)")
            }
            Err(PushError::Closed) => err_json("shutting down"),
        }
    }

    fn handle_learn(&self, req: &Json) -> Json {
        let (Some(dep), Some(x), Some(y)) = (
            req.get("deployment").and_then(Json::as_str),
            req.get("x").and_then(Json::as_f64_vec),
            req.get("y").and_then(Json::as_usize),
        ) else {
            return err_json("learn needs deployment, x, y");
        };
        match self.registry.with_mut(dep, |d| d.learn(&x, y).map(|_| {
            (d.n_train(), d.version)
        })) {
            Ok(Ok((n, v))) => {
                self.metrics.online_updates.fetch_add(1, Ordering::Relaxed);
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("n_train", Json::Num(n as f64)),
                    ("version", Json::Num(v as f64)),
                ])
            }
            Ok(Err(e)) | Err(e) => err_json(&e.to_string()),
        }
    }

    fn handle_unlearn(&self, req: &Json) -> Json {
        let (Some(dep), Some(idx)) = (
            req.get("deployment").and_then(Json::as_str),
            req.get("index").and_then(Json::as_usize),
        ) else {
            return err_json("unlearn needs deployment, index");
        };
        match self.registry.with_mut(dep, |d| d.unlearn(idx).map(|_| {
            (d.n_train(), d.version)
        })) {
            Ok(Ok((n, v))) => {
                self.metrics.online_updates.fetch_add(1, Ordering::Relaxed);
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("n_train", Json::Num(n as f64)),
                    ("version", Json::Num(v as f64)),
                ])
            }
            Ok(Err(e)) | Err(e) => err_json(&e.to_string()),
        }
    }

    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: close the batcher and join workers.
    pub fn shutdown(mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Build the predict-response object from a per-label p-value row,
/// via the classifier's canonical set/forced helpers so the wire
/// answers match `FullCp` exactly (including argmax tie-breaking).
fn predict_json(ps: &[f64], eps: f64) -> Json {
    let set: Vec<Json> = set_from_p_values(ps, eps)
        .into_iter()
        .map(|y| Json::Num(y as f64))
        .collect();
    let forced = forced_from_p_values(ps).label;
    Json::obj(vec![
        ("p_values", Json::from_f64_slice(ps)),
        ("set", Json::Arr(set)),
        ("forced", Json::Num(forced as f64)),
    ])
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
}

/// Serve a TCP listener until a `shutdown` op arrives. One thread per
/// connection (connections are expected to be few and long-lived; the
/// concurrency knob that matters is the worker pool).
pub fn serve(server: Arc<Server>, listener: TcpListener) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !server.stopping() {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let srv = server.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(srv, stream);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(server: Arc<Server>, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Json::parse(&line) {
            Ok(req) => server.handle(&req),
            Err(e) => err_json(&format!("bad json: {e}")),
        };
        writer.write_all(resp.encode().as_bytes())?;
        writer.write_all(b"\n")?;
        if server.stopping() {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MeasureConfig, MeasureKind};
    use crate::coordinator::state::Deployment;
    use crate::data::{make_classification, ClassificationSpec};

    fn test_server() -> Arc<Server> {
        let ds = make_classification(
            &ClassificationSpec {
                n_samples: 40,
                ..Default::default()
            },
            1,
        );
        let reg = Arc::new(Registry::new());
        reg.insert(Deployment::train(
            "knn",
            MeasureKind::SimplifiedKnn,
            &MeasureConfig {
                k: 3,
                ..Default::default()
            },
            &ds,
            None,
        ));
        Arc::new(Server::start(
            ServeConfig {
                workers: 2,
                max_wait_us: 100,
                ..Default::default()
            },
            reg,
        ))
    }

    #[test]
    fn predict_roundtrip_inprocess() {
        let srv = test_server();
        let req = Json::parse(
            r#"{"op":"predict","deployment":"knn","x":[0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0],"epsilon":0.05,"id":7}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("id").unwrap().as_f64(), Some(7.0));
        let ps = resp.get("p_values").unwrap().as_f64_vec().unwrap();
        assert_eq!(ps.len(), 2);
        assert!(resp.get("forced").is_some());
    }

    #[test]
    fn learn_increases_n() {
        let srv = test_server();
        let x: Vec<f64> = vec![0.0; 30];
        let req = Json::obj(vec![
            ("op", Json::Str("learn".into())),
            ("deployment", Json::Str("knn".into())),
            ("x", Json::from_f64_slice(&x)),
            ("y", Json::Num(1.0)),
        ]);
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("n_train").unwrap().as_f64(), Some(41.0));
    }

    #[test]
    fn unknown_deployment_is_clean_error() {
        let srv = test_server();
        let req = Json::parse(
            r#"{"op":"predict","deployment":"nope","x":[1,2,3]}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn stats_and_list() {
        let srv = test_server();
        let list = srv.handle(&Json::parse(r#"{"op":"list"}"#).unwrap());
        let deps = list.get("deployments").unwrap().as_arr().unwrap();
        assert_eq!(deps.len(), 1);
        let stats = srv.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert!(stats.get("requests").is_some());
    }
}
