//! The serving front end: JSON-lines over TCP, dynamic batching, worker
//! pool, online updates, metrics, observability.
//!
//! Protocol (one JSON object per line, response mirrors request `id`;
//! full request/response reference with examples in PROTOCOL.md):
//!
//! ```text
//! -> {"op":"predict","deployment":"knn","x":[...],"epsilon":0.1,"id":1}
//! <- {"id":1,"p_values":[0.8,0.05],"set":[0],"forced":0}
//! -> {"op":"predict_region","deployment":"reg","x":[...],"epsilon":0.1,"y":3.2}
//! <- {"intervals":[[1.0,5.2]],"width":4.2,"hull":[1.0,5.2],"p_value":0.4}
//! -> {"op":"learn","deployment":"knn","x":[...],"y":1}
//! <- {"ok":true,"n_train":101,"version":1}
//! -> {"op":"unlearn","deployment":"knn","index":17}
//! -> {"op":"observe","tester":"drift","xs":[[...],[...]],"k":7,"seed":1}
//! <- {"ok":true,"p_values":[null,0.5],"log_martingale":-0.1,"n":2,"alarm":false}
//! -> {"op":"stats","deployment":"knn"} | {"op":"trace","limit":100}
//! -> {"op":"list"} | {"op":"ping"} | {"op":"shutdown"}
//! ```
//!
//! `predict` serves classification deployments, `predict_region` serves
//! regression deployments (both batched through the same dynamic
//! batcher); `learn` routes y by the deployment's kind (integer label
//! vs float target). `observe` feeds an online exchangeability tester
//! (auto-created per `tester` name on first use) via
//! [`ExchangeabilityTest::observe_batch`]. Unbounded interval endpoints
//! (±inf) serialize as JSON `null` — the in-tree encoder's
//! representation for non-finite numbers.
//!
//! Observability: `predict` may carry the true label `"y"` (and
//! `predict_region` its float `"y"`), which feeds the per-deployment
//! online validity monitor — empirical error rate vs. each tracked
//! epsilon, set-size / width histograms, p-value uniformity — all
//! surfaced by `op:"stats"` (optionally filtered by `deployment`)
//! alongside the global counters, per-op latency blocks, and tester
//! martingales. `op:"trace"` dumps the stage-span ring in Chrome trace
//! format. Instrumentation reads clocks and finished outputs only; the
//! exact scoring path is untouched (EXACTNESS.md).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::ServeConfig;
use crate::coordinator::batcher::{Batcher, PushError};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::state::{RegionAnswer, Registry};
use crate::cp::classifier::{forced_from_p_values, set_from_p_values};
use crate::cp::measure::CpMeasure;
use crate::measures::KnnOptimized;
use crate::obs::metrics::{ObsRegistry, OpKind};
use crate::obs::trace::{self as obs_trace, Stage};
use crate::online::ExchangeabilityTest;
use crate::util::json::Json;

/// What a queued job asks for.
enum JobPayload {
    /// classification: per-label p-values -> set/forced answer; `truth`
    /// is the optional true label for online validity monitoring
    PValues { truth: Option<usize> },
    /// regression: exact interval region, optionally also the p-value
    /// of a candidate label
    Region { y: Option<f64> },
}

/// One queued prediction job.
struct Job {
    deployment: String,
    x: Vec<f64>,
    eps: f64,
    payload: JobPayload,
    enqueued: Instant,
    resp: mpsc::Sender<Json>,
}

/// The coordinator server: registry + batcher + workers + metrics +
/// per-deployment observability.
pub struct Server {
    pub registry: Arc<Registry>,
    pub metrics: Arc<Metrics>,
    /// per-deployment × per-op metric blocks and validity monitors
    pub obs: Arc<ObsRegistry>,
    batcher: Arc<Batcher<Job>>,
    cfg: ServeConfig,
    workers: Vec<std::thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// online exchangeability testers, auto-created per name by the
    /// `observe` op (write path — not routed through the batcher; the
    /// caller already batches via the `xs` payload)
    testers: RwLock<HashMap<String, ExchangeabilityTest<Box<dyn CpMeasure>>>>,
}

impl Server {
    /// Start the worker pool (does not bind the socket — see [`serve`]).
    pub fn start(cfg: ServeConfig, registry: Arc<Registry>) -> Server {
        if cfg.obs.trace {
            // install the ring (first init wins) and switch spans on
            obs_trace::init(cfg.obs.ring_capacity);
            obs_trace::set_enabled(true);
        }
        let obs = Arc::new(ObsRegistry::new(cfg.obs.epsilons.clone()));
        let metrics = Arc::new(Metrics::new());
        let batcher = Arc::new(Batcher::new(
            cfg.max_batch,
            Duration::from_micros(cfg.max_wait_us),
            cfg.queue_depth,
        ));
        let stop = Arc::new(AtomicBool::new(false));
        // THREADS: worker pool of cfg.workers detached scorer threads;
        // they exit when the batcher is closed and drained (next_batch
        // returns None) and are joined in `shutdown`.
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let b = batcher.clone();
                let reg = registry.clone();
                let met = metrics.clone();
                let ob = obs.clone();
                std::thread::spawn(move || {
                    while let Some(d) = b.next_batch_stats() {
                        met.record_batch(d.items.len());
                        met.set_queue_depth(d.depth_after);
                        obs_trace::record_complete(
                            Stage::BatchAssemble,
                            d.started,
                            d.assembled,
                            [d.items.len() as u64, d.depth_after as u64, 0, 0],
                        );
                        Self::run_batch(&reg, &met, &ob, d.items);
                    }
                })
            })
            .collect();
        Server {
            registry,
            metrics,
            obs,
            batcher,
            cfg,
            workers,
            stop,
            testers: RwLock::new(HashMap::new()),
        }
    }

    /// Score one drained batch. Jobs are grouped by (deployment, payload
    /// kind) — preserving arrival order within each group — and scored
    /// with one batched registry call per `LOCK_CHUNK`-job sub-chunk:
    /// `Deployment::p_values_batch` for classification jobs (each test
    /// object's distance/kernel row computed once rather than once per
    /// candidate label), `Deployment::region_rows` for regression jobs
    /// (one `coefficients_batch` per chunk; eps and candidate label may
    /// differ per job because only the sweep depends on them). Workers
    /// each drain their own batch, so the existing pool still fans
    /// chunks out across cores.
    fn run_batch(
        reg: &Registry,
        met: &Metrics,
        obs: &ObsRegistry,
        batch: Vec<Job>,
    ) {
        let mut groups: Vec<(String, bool, Vec<Job>)> = Vec::new();
        for job in batch {
            let is_region = matches!(job.payload, JobPayload::Region { .. });
            match groups
                .iter_mut()
                .find(|(d, r, _)| *d == job.deployment && *r == is_region)
            {
                Some((_, _, jobs)) => jobs.push(job),
                None => {
                    let dep = job.deployment.clone();
                    groups.push((dep, is_region, vec![job]));
                }
            }
        }
        // Lock-hold bound: the read lock is reacquired per sub-chunk so
        // a pending learn/unlearn (write lock) waits for at most one
        // chunk, not a whole group — the old per-job path released the
        // lock between jobs; this is the same fairness at 1/CHUNK the
        // acquisitions. Within a chunk each object's row reuse across
        // labels (the main batch win) is fully preserved.
        const LOCK_CHUNK: usize = 16;
        for (dep, is_region, jobs) in groups {
            let dep_obs = obs.get(&dep);
            dep_obs.record_batch(jobs.len());
            for chunk in jobs.chunks(LOCK_CHUNK) {
                if obs_trace::enabled() {
                    // queue-wait spans, retroactive: enqueue -> scoring
                    for job in chunk {
                        obs_trace::record_complete(
                            Stage::QueueWait,
                            job.enqueued,
                            job.enqueued.elapsed(),
                            [chunk.len() as u64, 0, 0, 0],
                        );
                    }
                }
                let xs: Vec<&[f64]> =
                    chunk.iter().map(|j| j.x.as_slice()).collect();
                let outs: Result<Vec<Json>> = if is_region {
                    let eps: Vec<f64> = chunk.iter().map(|j| j.eps).collect();
                    let ys: Vec<Option<f64>> = chunk
                        .iter()
                        .map(|j| match j.payload {
                            JobPayload::Region { y } => y,
                            JobPayload::PValues { .. } => None,
                        })
                        .collect();
                    reg.with(&dep, |d| d.region_rows(&xs, &eps, &ys))
                        .and_then(|r| r)
                        .map(|rows| {
                            rows.iter()
                                .map(|ans| {
                                    // width/p-at-y feed the validity
                                    // monitor from finished outputs only
                                    dep_obs.validity.record_region(
                                        ans.region.total_width(),
                                        ans.p_at_y,
                                    );
                                    region_json(ans)
                                })
                                .collect()
                        })
                } else {
                    reg.with(&dep, |d| -> Result<Vec<Vec<f64>>> {
                        if d.is_regression() {
                            bail!(
                                "deployment {dep:?} is a regression \
                                 deployment (use op \"predict_region\")"
                            );
                        }
                        Ok(d.p_values_batch(&xs))
                    })
                    .and_then(|r| r)
                    .map(|rows| {
                        rows.iter()
                            .zip(chunk)
                            .map(|(ps, job)| {
                                let truth = match job.payload {
                                    JobPayload::PValues { truth } => truth,
                                    JobPayload::Region { .. } => None,
                                };
                                dep_obs
                                    .validity
                                    .record_classification(ps, truth);
                                predict_json(ps, job.eps)
                            })
                            .collect()
                    })
                };
                match outs {
                    Ok(outs) => {
                        debug_assert_eq!(outs.len(), chunk.len());
                        for (job, out) in chunk.iter().zip(outs) {
                            met.observe_latency_us(
                                job.enqueued.elapsed().as_micros() as u64,
                            );
                            met.predictions.fetch_add(1, Ordering::Relaxed);
                            let _ = job.resp.send(out);
                        }
                    }
                    Err(e) => {
                        // metrics parity with the success arm (and the
                        // old per-job loop): failed jobs still count as
                        // served predictions and contribute latency
                        let msg = e.to_string();
                        for job in chunk {
                            met.observe_latency_us(
                                job.enqueued.elapsed().as_micros() as u64,
                            );
                            met.predictions.fetch_add(1, Ordering::Relaxed);
                            let _ = job.resp.send(err_json(&msg));
                        }
                    }
                }
            }
        }
    }

    /// Handle one request object (in-process entry point; the TCP layer
    /// and the tests both go through here).
    pub fn handle(&self, req: &Json) -> Json {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let id = req.get("id").cloned().unwrap_or(Json::Null);
        let mut out = match req.get("op").and_then(Json::as_str) {
            Some("predict") => self.handle_predict(req),
            Some("predict_region") => self.handle_predict_region(req),
            Some("observe") => self.handle_observe(req),
            Some("learn") => self.handle_learn(req),
            Some("unlearn") => self.handle_unlearn(req),
            Some("stats") => self.handle_stats(req),
            Some("trace") => self.handle_trace(req),
            Some("list") => Json::obj(vec![(
                "deployments",
                Json::Arr(
                    self.registry
                        .names()
                        .into_iter()
                        .map(Json::Str)
                        .collect(),
                ),
            )]),
            Some("ping") => Json::obj(vec![("ok", Json::Bool(true))]),
            Some("shutdown") => {
                self.stop.store(true, Ordering::SeqCst);
                Json::obj(vec![("ok", Json::Bool(true))])
            }
            _ => err_json("unknown or missing op"),
        };
        if let Json::Obj(m) = &mut out {
            m.insert("id".into(), id);
        }
        out
    }

    /// Push one job through the batcher and wait for its answer.
    ///
    /// EVERY exit arm records latency — success and error into the
    /// per-deployment op block, and additionally into the global
    /// histogram on the arms the worker never sees (rejected, closed,
    /// timed out). Without those arms the tail quantiles would be
    /// survivorship-biased exactly when the server sheds load.
    fn enqueue(
        &self,
        dep: &str,
        kind: OpKind,
        x: Vec<f64>,
        eps: f64,
        payload: JobPayload,
    ) -> Json {
        let dep_obs = self.obs.get(dep);
        let op = dep_obs.op(kind);
        let (tx, rx) = mpsc::channel();
        let start = Instant::now();
        let job = Job {
            deployment: dep.to_string(),
            x,
            eps,
            payload,
            enqueued: start,
            resp: tx,
        };
        match self.batcher.push(job) {
            Ok(()) => match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(j) => {
                    let us = start.elapsed().as_micros() as u64;
                    if j.get("ok").and_then(Json::as_bool) == Some(false) {
                        op.record_error(us);
                    } else {
                        op.record_ok(us);
                    }
                    j
                }
                Err(_) => {
                    let us = start.elapsed().as_micros() as u64;
                    self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    self.metrics.observe_latency_us(us);
                    op.record_error(us);
                    err_json("prediction timed out")
                }
            },
            Err(PushError::Full) => {
                let us = start.elapsed().as_micros() as u64;
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.observe_latency_us(us);
                op.record_rejected(us);
                err_json("overloaded (backpressure)")
            }
            Err(PushError::Closed) => {
                let us = start.elapsed().as_micros() as u64;
                self.metrics.observe_latency_us(us);
                op.record_error(us);
                err_json("shutting down")
            }
        }
    }

    fn handle_predict(&self, req: &Json) -> Json {
        let Some(dep) = req.get("deployment").and_then(Json::as_str) else {
            return err_json("missing deployment");
        };
        let Some(x) = req.get("x").and_then(Json::as_f64_vec) else {
            return err_json("missing x");
        };
        let eps = req
            .get("epsilon")
            .and_then(Json::as_f64)
            .unwrap_or(self.cfg.default_epsilon);
        // optional true label: feeds the online validity monitor only,
        // never the prediction itself
        let truth = req.get("y").and_then(Json::as_usize);
        self.enqueue(dep, OpKind::Predict, x, eps, JobPayload::PValues {
            truth,
        })
    }

    /// Regression prediction: exact interval region (optionally with the
    /// p-value of a candidate `y`), batched like `predict`.
    fn handle_predict_region(&self, req: &Json) -> Json {
        let Some(dep) = req.get("deployment").and_then(Json::as_str) else {
            return err_json("missing deployment");
        };
        let Some(x) = req.get("x").and_then(Json::as_f64_vec) else {
            return err_json("missing x");
        };
        let eps = req
            .get("epsilon")
            .and_then(Json::as_f64)
            .unwrap_or(self.cfg.default_epsilon);
        let y = req.get("y").and_then(Json::as_f64);
        self.enqueue(dep, OpKind::PredictRegion, x, eps, JobPayload::Region {
            y,
        })
    }

    /// Feed observations to a named exchangeability tester (created on
    /// first use with `k`/`seed` from the request; the first batch fixes
    /// the observation dimension). Accepts a single `"x"` row or a
    /// batched `"xs"` payload, scored through
    /// [`ExchangeabilityTest::observe_batch`].
    fn handle_observe(&self, req: &Json) -> Json {
        let name = req
            .get("tester")
            .and_then(Json::as_str)
            .unwrap_or("default");
        let rows: Vec<Vec<f64>> =
            if let Some(arr) = req.get("xs").and_then(Json::as_arr) {
                let mut rows = Vec::with_capacity(arr.len());
                for v in arr {
                    match v.as_f64_vec() {
                        Some(r) => rows.push(r),
                        None => {
                            return err_json(
                                "xs must be an array of float arrays",
                            )
                        }
                    }
                }
                rows
            } else if let Some(x) = req.get("x").and_then(Json::as_f64_vec) {
                vec![x]
            } else {
                return err_json("observe needs x or xs");
            };
        if rows.is_empty() {
            return err_json("observe needs at least one observation");
        }
        let dim = rows[0].len();
        if dim == 0 || rows.iter().any(|r| r.len() != dim) {
            return err_json("observations must share a nonzero dimension");
        }
        let k = req.get("k").and_then(Json::as_usize).unwrap_or(7).max(1);
        let seed = req.get("seed").and_then(Json::as_usize).unwrap_or(1);
        let _span =
            obs_trace::span_args(Stage::Observe, [rows.len() as u64, 0, 0, 0]);
        // LOCK-ORDER: coordinator.testers — exclusive while the tester
        // observes the batch; never held with coordinator.registry.
        let mut guard = self.testers.write().unwrap();
        let tester = guard.entry(name.to_string()).or_insert_with(|| {
            let measure: Box<dyn CpMeasure> =
                Box::new(KnnOptimized::new(k, true));
            ExchangeabilityTest::new(measure, dim, seed as u64)
        });
        if tester.dim() != dim {
            return err_json(&format!(
                "tester {name:?} expects dimension {}, got {dim}",
                tester.dim()
            ));
        }
        let xs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let ps = tester.observe_batch(&xs);
        let lm = tester.log_martingale();
        let n = tester.seen();
        drop(guard);
        self.metrics
            .online_updates
            .fetch_add(rows.len() as u64, Ordering::Relaxed);
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "p_values",
                Json::Arr(
                    ps.into_iter()
                        .map(|p| p.map_or(Json::Null, Json::Num))
                        .collect(),
                ),
            ),
            ("log_martingale", Json::Num(lm)),
            ("n", Json::Num(n as f64)),
            ("alarm", Json::Bool(lm > 100f64.ln())),
        ])
    }

    fn handle_learn(&self, req: &Json) -> Json {
        let (Some(dep), Some(x), Some(y)) = (
            req.get("deployment").and_then(Json::as_str),
            req.get("x").and_then(Json::as_f64_vec),
            req.get("y").and_then(Json::as_f64),
        ) else {
            return err_json("learn needs deployment, x, y");
        };
        let start = Instant::now();
        let _span = obs_trace::span(Stage::Learn);
        // y routes on the deployment kind: float target for regression,
        // non-negative integer label for classification
        let res = self.registry.with_mut(dep, |d| {
            if d.is_regression() {
                d.learn_reg(&x, y).map(|_| (d.n_train(), d.version))
            } else if y < 0.0 || y.fract() != 0.0 {
                bail!(
                    "classification deployment needs a non-negative \
                     integer y, got {y}"
                )
            } else {
                d.learn(&x, y as usize).map(|_| (d.n_train(), d.version))
            }
        });
        let op = self.obs.get(dep);
        let op = op.op(OpKind::Learn);
        let us = start.elapsed().as_micros() as u64;
        match res {
            Ok(Ok((n, v))) => {
                op.record_ok(us);
                self.metrics.online_updates.fetch_add(1, Ordering::Relaxed);
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("n_train", Json::Num(n as f64)),
                    ("version", Json::Num(v as f64)),
                ])
            }
            Ok(Err(e)) | Err(e) => {
                op.record_error(us);
                err_json(&e.to_string())
            }
        }
    }

    fn handle_unlearn(&self, req: &Json) -> Json {
        let (Some(dep), Some(idx)) = (
            req.get("deployment").and_then(Json::as_str),
            req.get("index").and_then(Json::as_usize),
        ) else {
            return err_json("unlearn needs deployment, index");
        };
        let start = Instant::now();
        let _span = obs_trace::span(Stage::Unlearn);
        let res = self.registry.with_mut(dep, |d| d.unlearn(idx).map(|_| {
            (d.n_train(), d.version)
        }));
        let op = self.obs.get(dep);
        let op = op.op(OpKind::Unlearn);
        let us = start.elapsed().as_micros() as u64;
        match res {
            Ok(Ok((n, v))) => {
                op.record_ok(us);
                self.metrics.online_updates.fetch_add(1, Ordering::Relaxed);
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("n_train", Json::Num(n as f64)),
                    ("version", Json::Num(v as f64)),
                ])
            }
            Ok(Err(e)) | Err(e) => {
                op.record_error(us);
                err_json(&e.to_string())
            }
        }
    }

    /// `op:"stats"`: the global metrics snapshot, augmented with the
    /// live batcher depth, the per-deployment observability blocks
    /// (optionally narrowed by `"deployment"`), the online testers'
    /// martingale state, and the tracer's status.
    fn handle_stats(&self, req: &Json) -> Json {
        let mut out = self.metrics.snapshot();
        let Json::Obj(m) = &mut out else {
            return out;
        };
        m.insert(
            "queue_depth".into(),
            Json::Num(self.batcher.depth() as f64),
        );
        let deployments = match req.get("deployment").and_then(Json::as_str) {
            Some(name) => {
                let mut only = std::collections::BTreeMap::new();
                if let Some(d) = self.obs.peek(name) {
                    only.insert(name.to_string(), d.snapshot());
                }
                Json::Obj(only)
            }
            None => self.obs.snapshot(),
        };
        m.insert("deployments".into(), deployments);
        m.insert(
            "epsilons".into(),
            Json::Arr(
                self.obs.epsilons().iter().map(|&e| Json::Num(e)).collect(),
            ),
        );
        let testers = {
            // LOCK-ORDER: coordinator.testers — read-only martingale
            // snapshot; no other lock taken while held.
            let guard = self.testers.read().unwrap();
            let mut map = std::collections::BTreeMap::new();
            for (name, t) in guard.iter() {
                let lm = t.log_martingale();
                map.insert(
                    name.clone(),
                    Json::obj(vec![
                        ("n", Json::Num(t.seen() as f64)),
                        ("log_martingale", Json::Num(lm)),
                        ("log_max_power", Json::Num(t.log_max_power())),
                        ("alarm", Json::Bool(lm > 100f64.ln())),
                    ]),
                );
            }
            Json::Obj(map)
        };
        m.insert("testers".into(), testers);
        let trace = match obs_trace::tracer() {
            Some(t) => Json::obj(vec![
                ("enabled", Json::Bool(obs_trace::enabled())),
                ("recorded", Json::Num(t.ring().recorded() as f64)),
                ("capacity", Json::Num(t.ring().capacity() as f64)),
            ]),
            None => Json::obj(vec![
                ("enabled", Json::Bool(false)),
                ("recorded", Json::Num(0.0)),
                ("capacity", Json::Num(0.0)),
            ]),
        };
        m.insert("trace".into(), trace);
        out
    }

    /// `op:"trace"`: dump the span ring in Chrome trace format
    /// (`chrome://tracing` / Perfetto compatible), newest-`limit`
    /// events when `"limit"` is given.
    fn handle_trace(&self, req: &Json) -> Json {
        let limit = req.get("limit").and_then(Json::as_usize);
        let events = match obs_trace::tracer() {
            Some(t) => {
                let mut evs = t.ring().snapshot();
                if let Some(n) = limit {
                    if evs.len() > n {
                        evs.drain(..evs.len() - n);
                    }
                }
                evs
            }
            None => Vec::new(),
        };
        let mut out = obs_trace::chrome_trace_json(&events);
        if let Json::Obj(m) = &mut out {
            m.insert("enabled".into(), Json::Bool(obs_trace::enabled()));
            m.insert(
                "recorded".into(),
                Json::Num(obs_trace::tracer().map_or(0, |t| {
                    t.ring().recorded()
                }) as f64),
            );
        }
        out
    }

    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: close the batcher and join workers.
    pub fn shutdown(mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Build the predict-response object from a per-label p-value row,
/// via the classifier's canonical set/forced helpers so the wire
/// answers match `FullCp` exactly (including argmax tie-breaking).
fn predict_json(ps: &[f64], eps: f64) -> Json {
    let set: Vec<Json> = set_from_p_values(ps, eps)
        .into_iter()
        .map(|y| Json::Num(y as f64))
        .collect();
    let forced = forced_from_p_values(ps).label;
    Json::obj(vec![
        ("p_values", Json::from_f64_slice(ps)),
        ("set", Json::Arr(set)),
        ("forced", Json::Num(forced as f64)),
    ])
}

/// Build the predict_region response from one batched answer:
/// `intervals` as `[lo, hi]` pairs, total `width`, the convex `hull`
/// (null for an empty region), and the candidate label's `p_value` when
/// the request supplied a `y`. Non-finite numbers (unbounded endpoints,
/// infinite width) serialize as JSON null.
fn region_json(ans: &RegionAnswer) -> Json {
    let intervals: Vec<Json> = ans
        .region
        .intervals
        .iter()
        .map(|iv| Json::Arr(vec![Json::Num(iv.lo), Json::Num(iv.hi)]))
        .collect();
    let hull = match ans.region.hull() {
        Some(h) => Json::Arr(vec![Json::Num(h.lo), Json::Num(h.hi)]),
        None => Json::Null,
    };
    let mut fields = vec![
        ("intervals", Json::Arr(intervals)),
        ("width", Json::Num(ans.region.total_width())),
        ("hull", hull),
    ];
    if let Some(p) = ans.p_at_y {
        fields.push(("p_value", Json::Num(p)));
    }
    Json::obj(fields)
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
}

/// Serve a TCP listener until a `shutdown` op arrives. One thread per
/// connection (connections are expected to be few and long-lived; the
/// concurrency knob that matters is the worker pool).
pub fn serve(server: Arc<Server>, listener: TcpListener) -> Result<()> {
    listener.set_nonblocking(true)?;
    // THREADS: one handler thread per accepted connection, all joined
    // before this function returns; handlers take no locks directly
    // (they go through Server::handle).
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !server.stopping() {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let srv = server.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(srv, stream);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(server: Arc<Server>, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Json::parse(&line) {
            Ok(req) => server.handle(&req),
            Err(e) => err_json(&format!("bad json: {e}")),
        };
        {
            let encoded = resp.encode();
            let _span = obs_trace::span_args(
                Stage::RespWrite,
                [encoded.len() as u64, 0, 0, 0],
            );
            writer.write_all(encoded.as_bytes())?;
            writer.write_all(b"\n")?;
        }
        if server.stopping() {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MeasureConfig, MeasureKind};
    use crate::coordinator::state::Deployment;
    use crate::data::{make_classification, ClassificationSpec};

    fn test_server() -> Arc<Server> {
        let ds = make_classification(
            &ClassificationSpec {
                n_samples: 40,
                ..Default::default()
            },
            1,
        );
        let reg = Arc::new(Registry::new());
        reg.insert(Deployment::train(
            "knn",
            MeasureKind::SimplifiedKnn,
            &MeasureConfig {
                k: 3,
                ..Default::default()
            },
            &ds,
            None,
        ));
        Arc::new(Server::start(
            ServeConfig {
                workers: 2,
                max_wait_us: 100,
                ..Default::default()
            },
            reg,
        ))
    }

    fn test_server_with_regression() -> Arc<Server> {
        use crate::config::RegressorKind;
        use crate::data::{make_regression, RegressionSpec};
        let srv = test_server();
        let rds = make_regression(
            &RegressionSpec {
                n_samples: 30,
                n_features: 4,
                n_informative: 3,
                noise: 3.0,
            },
            2,
        );
        srv.registry.insert(Deployment::train_regression(
            "reg",
            RegressorKind::Knn,
            &MeasureConfig {
                k: 3,
                ..Default::default()
            },
            &rds,
            None,
        ));
        srv
    }

    #[test]
    fn predict_region_roundtrip_inprocess() {
        let srv = test_server_with_regression();
        let req = Json::parse(
            r#"{"op":"predict_region","deployment":"reg","x":[0,0,0,0],"epsilon":0.1,"y":0.0,"id":3}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("id").unwrap().as_f64(), Some(3.0));
        let intervals = resp.get("intervals").unwrap().as_arr().unwrap();
        assert!(!intervals.is_empty());
        assert!(resp.get("hull").is_some());
        let p = resp.get("p_value").unwrap().as_f64().unwrap();
        assert!(p > 0.0 && p <= 1.0);
        // without y there is no p_value field
        let req = Json::parse(
            r#"{"op":"predict_region","deployment":"reg","x":[0,0,0,0]}"#,
        )
        .unwrap();
        assert!(srv.handle(&req).get("p_value").is_none());
    }

    #[test]
    fn wrong_op_for_deployment_kind_is_clean_error() {
        let srv = test_server_with_regression();
        // predict on a regression deployment
        let req = Json::parse(
            r#"{"op":"predict","deployment":"reg","x":[0,0,0,0]}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        // predict_region on a classification deployment
        let req = Json::parse(
            r#"{"op":"predict_region","deployment":"knn","x":[0,0,0]}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn learn_routes_float_labels_to_regression() {
        let srv = test_server_with_regression();
        let req = Json::parse(
            r#"{"op":"learn","deployment":"reg","x":[0.5,0.5,0.5,0.5],"y":1.25}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("n_train").unwrap().as_f64(), Some(31.0));
        // float label on a classification deployment is rejected
        let req = Json::parse(
            r#"{"op":"learn","deployment":"knn","x":[0,0,0],"y":0.5}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn observe_batch_roundtrip_inprocess() {
        let srv = test_server();
        let req = Json::parse(
            r#"{"op":"observe","tester":"t","xs":[[0,0,0],[0.5,0.1,0.2],[0.1,0.4,0.3]],"k":3,"seed":1}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        let ps = resp.get("p_values").unwrap().as_arr().unwrap();
        assert_eq!(ps.len(), 3);
        assert!(matches!(ps[0], Json::Null), "bootstrap p is null");
        assert!(ps[1].as_f64().is_some());
        assert_eq!(resp.get("n").unwrap().as_f64(), Some(3.0));
        assert!(resp.get("log_martingale").unwrap().as_f64().is_some());
        // the tester persists across requests
        let req = Json::parse(
            r#"{"op":"observe","tester":"t","x":[0.2,0.2,0.2]}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("n").unwrap().as_f64(), Some(4.0));
        assert!(resp.get("p_values").unwrap().as_arr().unwrap()[0]
            .as_f64()
            .is_some());
        // dimension mismatch is a clean error
        let req = Json::parse(
            r#"{"op":"observe","tester":"t","x":[0.2,0.2]}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn predict_roundtrip_inprocess() {
        let srv = test_server();
        let req = Json::parse(
            r#"{"op":"predict","deployment":"knn","x":[0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0],"epsilon":0.05,"id":7}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("id").unwrap().as_f64(), Some(7.0));
        let ps = resp.get("p_values").unwrap().as_f64_vec().unwrap();
        assert_eq!(ps.len(), 2);
        assert!(resp.get("forced").is_some());
    }

    #[test]
    fn learn_increases_n() {
        let srv = test_server();
        let x: Vec<f64> = vec![0.0; 30];
        let req = Json::obj(vec![
            ("op", Json::Str("learn".into())),
            ("deployment", Json::Str("knn".into())),
            ("x", Json::from_f64_slice(&x)),
            ("y", Json::Num(1.0)),
        ]);
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("n_train").unwrap().as_f64(), Some(41.0));
    }

    #[test]
    fn unknown_deployment_is_clean_error() {
        let srv = test_server();
        let req = Json::parse(
            r#"{"op":"predict","deployment":"nope","x":[1,2,3]}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn stats_and_list() {
        let srv = test_server();
        let list = srv.handle(&Json::parse(r#"{"op":"list"}"#).unwrap());
        let deps = list.get("deployments").unwrap().as_arr().unwrap();
        assert_eq!(deps.len(), 1);
        let stats = srv.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert!(stats.get("requests").is_some());
    }

    #[test]
    fn stats_reports_per_deployment_observability() {
        let srv = test_server();
        let x = vec![0.0; 30];
        // labeled predict: "y" feeds the validity monitor
        let req = Json::obj(vec![
            ("op", Json::Str("predict".into())),
            ("deployment", Json::Str("knn".into())),
            ("x", Json::from_f64_slice(&x)),
            ("y", Json::Num(1.0)),
        ]);
        assert!(srv.handle(&req).get("p_values").is_some());
        let stats = srv.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        for key in ["deployments", "epsilons", "testers", "trace", "queue_depth"]
        {
            assert!(stats.get(key).is_some(), "missing {key}");
        }
        let knn = stats.get("deployments").unwrap().get("knn").unwrap();
        let predict = knn.get("ops").unwrap().get("predict").unwrap();
        assert_eq!(predict.get("requests").unwrap().as_f64(), Some(1.0));
        assert_eq!(predict.get("errors").unwrap().as_f64(), Some(0.0));
        let validity = knn.get("validity").unwrap();
        let tracks = validity.get("per_epsilon").unwrap().as_arr().unwrap();
        assert!(!tracks.is_empty(), "default epsilons must be tracked");
        assert_eq!(tracks[0].get("labeled").unwrap().as_f64(), Some(1.0));
        // filter narrows to the named deployment; unknown names are empty
        let one = srv.handle(
            &Json::parse(r#"{"op":"stats","deployment":"knn"}"#).unwrap(),
        );
        assert!(one.get("deployments").unwrap().get("knn").is_some());
        let none = srv.handle(
            &Json::parse(r#"{"op":"stats","deployment":"nope"}"#).unwrap(),
        );
        assert!(none.get("deployments").unwrap().get("nope").is_none());
    }

    #[test]
    fn rejected_and_error_arms_record_latency() {
        // queue_depth 0 => every push is rejected with backpressure
        let ds = make_classification(
            &ClassificationSpec {
                n_samples: 40,
                ..Default::default()
            },
            1,
        );
        let reg = Arc::new(Registry::new());
        reg.insert(Deployment::train(
            "knn",
            MeasureKind::SimplifiedKnn,
            &MeasureConfig {
                k: 3,
                ..Default::default()
            },
            &ds,
            None,
        ));
        let srv = Arc::new(Server::start(
            ServeConfig {
                workers: 1,
                queue_depth: 0,
                ..Default::default()
            },
            reg,
        ));
        let req = Json::parse(
            r#"{"op":"predict","deployment":"knn","x":[0,0,0]}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(srv.metrics.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(
            srv.metrics.latency_count(),
            1,
            "rejected arm must feed the latency histogram"
        );
        let op = srv.obs.get("knn");
        let op = op.op(OpKind::Predict);
        assert_eq!(op.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(op.latency.count(), 1);
    }

    #[test]
    fn trace_op_reports_ring_status() {
        let srv = test_server();
        let resp = srv.handle(&Json::parse(r#"{"op":"trace"}"#).unwrap());
        // well-formed whether or not another test installed the global
        // tracer: a traceEvents array plus status fields, always
        assert!(resp.get("traceEvents").unwrap().as_arr().is_some());
        assert!(resp.get("enabled").unwrap().as_bool().is_some());
        assert!(resp.get("recorded").unwrap().as_f64().is_some());
        let limited = srv.handle(
            &Json::parse(r#"{"op":"trace","limit":2}"#).unwrap(),
        );
        assert!(
            limited.get("traceEvents").unwrap().as_arr().unwrap().len() <= 2
        );
    }

    #[test]
    fn learn_failure_counts_in_op_block() {
        let srv = test_server();
        // float label on a classification deployment is rejected
        let req = Json::parse(
            r#"{"op":"learn","deployment":"knn","x":[0,0,0],"y":0.5}"#,
        )
        .unwrap();
        let resp = srv.handle(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        let dep = srv.obs.get("knn");
        let learn = dep.op(OpKind::Learn);
        assert_eq!(learn.requests.load(Ordering::Relaxed), 1);
        assert_eq!(learn.errors.load(Ordering::Relaxed), 1);
        assert_eq!(learn.latency.count(), 1, "error arm feeds latency");
    }
}
