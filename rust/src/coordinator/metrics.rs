//! Process-wide serving metrics: lock-free counters + fixed-bucket
//! latency / batch-size histograms, snapshotted as JSON for the
//! `stats` RPC.
//!
//! These are the *global* aggregates; per-deployment × per-op blocks
//! live in [`crate::obs::metrics::ObsRegistry`] and are merged into the
//! same `stats` answer by the server.
//!
//! The latency histogram is fed by EVERY response arm — success,
//! error, rejected (backpressure) and timeout — so tail quantiles are
//! not survivorship-biased under load shedding; `mean_latency_us` is
//! the histogram's own sum/count for the same reason (it used to divide
//! by the `predictions` counter, which silently excluded rejected
//! requests).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::hist::AtomicHist;
use crate::util::json::Json;

/// Coordinator metrics (all relaxed atomics; serving-side hot path).
pub struct Metrics {
    pub requests: AtomicU64,
    pub predictions: AtomicU64,
    pub online_updates: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    latency: AtomicHist,
    batch_sizes: AtomicHist,
    /// batcher queue depth, sampled by workers right after each drain
    queue_depth: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            predictions: AtomicU64::new(0),
            online_updates: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_items: AtomicU64::new(0),
            latency: AtomicHist::latency_us(),
            batch_sizes: AtomicHist::linear(64),
            queue_depth: AtomicU64::new(0),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe_latency_us(&self, us: u64) {
        self.latency.observe(us as f64);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(size as u64, Ordering::Relaxed);
        self.batch_sizes.observe(size as f64);
    }

    /// Gauge: batcher queue depth observed right after a drain.
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
    }

    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Approximate latency quantile from the histogram.
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        self.latency.quantile(q) as u64
    }

    /// Mean over every latency observation (all response arms).
    pub fn mean_latency_us(&self) -> f64 {
        self.latency.mean()
    }

    /// Total latency observations (== responses that fed the histogram).
    pub fn latency_count(&self) -> u64 {
        self.latency.count()
    }

    pub fn snapshot(&self) -> Json {
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        Json::obj(vec![
            ("requests", Json::Num(self.requests.load(Ordering::Relaxed) as f64)),
            (
                "predictions",
                Json::Num(self.predictions.load(Ordering::Relaxed) as f64),
            ),
            (
                "online_updates",
                Json::Num(self.online_updates.load(Ordering::Relaxed) as f64),
            ),
            ("rejected", Json::Num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("errors", Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
            ("batches", Json::Num(batches as f64)),
            (
                "mean_batch_size",
                Json::Num(if batches == 0 {
                    0.0
                } else {
                    items as f64 / batches as f64
                }),
            ),
            ("mean_latency_us", Json::Num(self.mean_latency_us())),
            ("p50_latency_us", Json::Num(self.latency_quantile_us(0.5) as f64)),
            ("p99_latency_us", Json::Num(self.latency_quantile_us(0.99) as f64)),
            ("latency_us", self.latency.snapshot()),
            ("batch_size", self.batch_sizes.snapshot()),
            ("queue_depth", Json::Num(self.queue_depth() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.observe_latency_us(80); // bucket <=100
        }
        for _ in 0..10 {
            m.observe_latency_us(400_000); // bucket <=1s
        }
        assert_eq!(m.latency_quantile_us(0.5), 100);
        assert_eq!(m.latency_quantile_us(0.99), 1_000_000);
    }

    #[test]
    fn snapshot_has_fields() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_batch(4);
        m.record_batch(2);
        let s = m.snapshot();
        assert_eq!(s.get("requests").unwrap().as_f64(), Some(3.0));
        assert_eq!(s.get("mean_batch_size").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn snapshot_keys_are_golden() {
        // wire-format stability: dashboards key on these names
        let s = Metrics::new().snapshot();
        for key in [
            "requests",
            "predictions",
            "online_updates",
            "rejected",
            "errors",
            "batches",
            "mean_batch_size",
            "mean_latency_us",
            "p50_latency_us",
            "p99_latency_us",
            "latency_us",
            "batch_size",
            "queue_depth",
        ] {
            assert!(s.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn mean_latency_counts_every_arm() {
        // the old mean divided by the predictions counter, so latency
        // recorded on rejected/error arms skewed it; now it is the
        // histogram's own mean
        let m = Metrics::new();
        m.observe_latency_us(100);
        m.observe_latency_us(300); // e.g. a rejected request's latency
        assert_eq!(m.latency_count(), 2);
        assert!((m.mean_latency_us() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn queue_depth_gauge() {
        let m = Metrics::new();
        assert_eq!(m.queue_depth(), 0);
        m.set_queue_depth(17);
        assert_eq!(m.queue_depth(), 17);
        assert_eq!(
            m.snapshot().get("queue_depth").unwrap().as_f64(),
            Some(17.0)
        );
    }

    #[test]
    fn empty_quantile_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.5), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
    }
}
