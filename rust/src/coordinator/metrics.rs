//! Serving metrics: lock-free counters + a fixed-bucket latency
//! histogram (microseconds, log-spaced), snapshotted as JSON for the
//! `stats` RPC.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// log-spaced latency bucket upper bounds, in microseconds
const BUCKETS_US: [u64; 12] = [
    50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 100_000, 1_000_000,
    u64::MAX,
];

/// Coordinator metrics (all relaxed atomics; serving-side hot path).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub predictions: AtomicU64,
    pub online_updates: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    latency: [AtomicU64; 12],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe_latency_us(&self, us: u64) {
        let idx = BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(11);
        self.latency[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Approximate latency quantile from the histogram.
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let total: u64 = self
            .latency
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.latency.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return BUCKETS_US[i];
            }
        }
        BUCKETS_US[11]
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.predictions.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn snapshot(&self) -> Json {
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        Json::obj(vec![
            ("requests", Json::Num(self.requests.load(Ordering::Relaxed) as f64)),
            (
                "predictions",
                Json::Num(self.predictions.load(Ordering::Relaxed) as f64),
            ),
            (
                "online_updates",
                Json::Num(self.online_updates.load(Ordering::Relaxed) as f64),
            ),
            ("rejected", Json::Num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("errors", Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
            ("batches", Json::Num(batches as f64)),
            (
                "mean_batch_size",
                Json::Num(if batches == 0 {
                    0.0
                } else {
                    items as f64 / batches as f64
                }),
            ),
            ("mean_latency_us", Json::Num(self.mean_latency_us())),
            ("p50_latency_us", Json::Num(self.latency_quantile_us(0.5) as f64)),
            ("p99_latency_us", Json::Num(self.latency_quantile_us(0.99) as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.observe_latency_us(80); // bucket <=100
        }
        for _ in 0..10 {
            m.observe_latency_us(400_000); // bucket <=1s
        }
        assert_eq!(m.latency_quantile_us(0.5), 100);
        assert_eq!(m.latency_quantile_us(0.99), 1_000_000);
    }

    #[test]
    fn snapshot_has_fields() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_batch(4);
        m.record_batch(2);
        let s = m.snapshot();
        assert_eq!(s.get("requests").unwrap().as_f64(), Some(3.0));
        assert_eq!(s.get("mean_batch_size").unwrap().as_f64(), Some(3.0));
    }
}
