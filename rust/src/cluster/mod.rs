//! Conformal clustering and anomaly detection (paper §9).
//!
//! * [`AnomalyDetector`] — conformal anomaly detection (Laxhammar &
//!   Falkman 2010): flag x as anomalous when its conformal p-value
//!   under the (Simplified k-NN) measure falls below eps. With the
//!   optimized measure each query is O(n) instead of O(n^2).
//! * [`conformal_clustering`] — Cherubin et al. (2015): lay a q x q
//!   grid over a 2-D projection of the data, compute the p-value of
//!   each grid-cell centre, keep cells with p > eps, and return the
//!   4-connected components as clusters. Cost O(n q^2) with the
//!   optimized measure vs O(n^2 q^2) standard (§9's accounting with
//!   p = 2).
//! * [`pca2`] — the 2-D projection substrate (top-2 principal
//!   components via power iteration with deflation).

use crate::cp::measure::CpMeasure;
use crate::cp::pvalue::p_value;
use crate::data::{Dataset, Rng};

/// Project rows onto their top-2 principal components.
///
/// Power iteration with Hotelling deflation on the p x p covariance —
/// adequate for the well-separated spectra of clustering workloads.
pub fn pca2(x: &[f64], p: usize) -> Vec<f64> {
    let n = x.len() / p;
    assert!(n > 1);
    // column means
    let mut mean = vec![0.0; p];
    for i in 0..n {
        for j in 0..p {
            mean[j] += x[i * p + j];
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    // covariance (p x p)
    let mut cov = vec![0.0; p * p];
    for i in 0..n {
        for a in 0..p {
            let da = x[i * p + a] - mean[a];
            for b in a..p {
                cov[a * p + b] += da * (x[i * p + b] - mean[b]);
            }
        }
    }
    for a in 0..p {
        for b in 0..a {
            cov[a * p + b] = cov[b * p + a];
        }
    }
    let matvec = |m: &[f64], v: &[f64], out: &mut [f64]| {
        for a in 0..p {
            out[a] = (0..p).map(|b| m[a * p + b] * v[b]).sum();
        }
    };
    let mut rng = Rng::seed_from(12345);
    let mut components: Vec<Vec<f64>> = Vec::new();
    let mut work = cov.clone();
    for _ in 0..2.min(p) {
        let mut v: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let mut tmp = vec![0.0; p];
        for _ in 0..200 {
            matvec(&work, &v, &mut tmp);
            let norm = tmp.iter().map(|t| t * t).sum::<f64>().sqrt();
            if norm < 1e-30 {
                break;
            }
            for (vi, t) in v.iter_mut().zip(&tmp) {
                *vi = t / norm;
            }
        }
        // deflate: work -= lambda v v^T
        matvec(&work, &v, &mut tmp);
        let lambda: f64 = v.iter().zip(&tmp).map(|(a, b)| a * b).sum();
        for a in 0..p {
            for b in 0..p {
                work[a * p + b] -= lambda * v[a] * v[b];
            }
        }
        components.push(v);
    }
    while components.len() < 2 {
        components.push(vec![0.0; p]); // degenerate p=1 input
    }
    // project
    let mut out = vec![0.0; n * 2];
    for i in 0..n {
        for (c, comp) in components.iter().enumerate() {
            out[i * 2 + c] = (0..p)
                .map(|j| (x[i * p + j] - mean[j]) * comp[j])
                .sum();
        }
    }
    out
}

/// Conformal anomaly detector over unlabelled observations.
pub struct AnomalyDetector<M: CpMeasure> {
    measure: M,
    eps: f64,
}

impl<M: CpMeasure> AnomalyDetector<M> {
    /// Train on normal observations (labels collapsed to one class).
    pub fn train(mut measure: M, x: &[f64], p: usize, eps: f64) -> Self {
        let n = x.len() / p;
        let ds = Dataset::new(x.to_vec(), vec![0; n], p, 1);
        measure.fit(&ds);
        AnomalyDetector { measure, eps }
    }

    /// Conformal p-value of an observation.
    pub fn p_value(&self, x: &[f64]) -> f64 {
        p_value(&self.measure.scores(x, 0))
    }

    /// Anomaly iff p <= eps (guaranteed <= eps false-alarm rate under
    /// exchangeability).
    pub fn is_anomaly(&self, x: &[f64]) -> bool {
        self.p_value(x) <= self.eps
    }

    /// Learn a confirmed-normal observation online (optimized measures).
    pub fn learn(&mut self, x: &[f64]) -> bool {
        self.measure.learn(x, 0)
    }
}

/// A conformal clustering result.
#[derive(Clone, Debug)]
pub struct Clustering {
    /// grid side length
    pub q: usize,
    /// cluster id per grid cell (usize::MAX = not in any cluster)
    pub cell_cluster: Vec<usize>,
    /// number of clusters found
    pub n_clusters: usize,
    /// cluster id per input point (usize::MAX = noise)
    pub point_cluster: Vec<usize>,
    /// grid bounding box in the projected plane
    pub bounds: [f64; 4],
}

/// Conformal clustering (Cherubin et al. 2015) on a 2-D projection.
///
/// `measure` scores grid-cell centres against the (projected) points;
/// cells whose conformal p-value exceeds `eps` form the clusters.
pub fn conformal_clustering<M: CpMeasure>(
    mut measure: M,
    x: &[f64],
    p: usize,
    q: usize,
    eps: f64,
) -> Clustering {
    let proj = if p == 2 { x.to_vec() } else { pca2(x, p) };
    let n = proj.len() / 2;
    let ds = Dataset::new(proj.clone(), vec![0; n], 2, 1);
    measure.fit(&ds);

    // bounding box with a margin of one cell
    let (mut x0, mut x1, mut y0, mut y1) =
        (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY, f64::NEG_INFINITY);
    for i in 0..n {
        x0 = x0.min(proj[i * 2]);
        x1 = x1.max(proj[i * 2]);
        y0 = y0.min(proj[i * 2 + 1]);
        y1 = y1.max(proj[i * 2 + 1]);
    }
    let dx = ((x1 - x0) / q as f64).max(1e-12);
    let dy = ((y1 - y0) / q as f64).max(1e-12);

    // p-value per cell centre
    let mut keep = vec![false; q * q];
    for gy in 0..q {
        for gx in 0..q {
            let cx = x0 + (gx as f64 + 0.5) * dx;
            let cy = y0 + (gy as f64 + 0.5) * dy;
            let pv = p_value(&measure.scores(&[cx, cy], 0));
            keep[gy * q + gx] = pv > eps;
        }
    }

    // 4-connected components over kept cells
    let mut cell_cluster = vec![usize::MAX; q * q];
    let mut n_clusters = 0usize;
    let mut stack = Vec::new();
    for start in 0..q * q {
        if !keep[start] || cell_cluster[start] != usize::MAX {
            continue;
        }
        let id = n_clusters;
        n_clusters += 1;
        stack.push(start);
        cell_cluster[start] = id;
        while let Some(c) = stack.pop() {
            let (gy, gx) = (c / q, c % q);
            let mut push = |ny: usize, nx: usize| {
                let nc = ny * q + nx;
                if keep[nc] && cell_cluster[nc] == usize::MAX {
                    cell_cluster[nc] = id;
                    stack.push(nc);
                }
            };
            if gx > 0 {
                push(gy, gx - 1);
            }
            if gx + 1 < q {
                push(gy, gx + 1);
            }
            if gy > 0 {
                push(gy - 1, gx);
            }
            if gy + 1 < q {
                push(gy + 1, gx);
            }
        }
    }

    // assign points to the cluster of their containing cell
    let point_cluster: Vec<usize> = (0..n)
        .map(|i| {
            let gx = (((proj[i * 2] - x0) / dx) as usize).min(q - 1);
            let gy = (((proj[i * 2 + 1] - y0) / dy) as usize).min(q - 1);
            cell_cluster[gy * q + gx]
        })
        .collect();

    Clustering {
        q,
        cell_cluster,
        n_clusters,
        point_cluster,
        bounds: [x0, x1, y0, y1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::measures::knn::KnnOptimized;

    /// two well-separated Gaussian blobs in 2-D
    fn blobs(n_per: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed_from(seed);
        let mut out = Vec::with_capacity(n_per * 4);
        for c in 0..2 {
            let off = c as f64 * 10.0;
            for _ in 0..n_per {
                out.push(off + 0.5 * rng.normal());
                out.push(off + 0.5 * rng.normal());
            }
        }
        out
    }

    #[test]
    fn clustering_finds_two_blobs() {
        let x = blobs(60, 1);
        let c = conformal_clustering(KnnOptimized::new(5, true), &x, 2, 24, 0.08);
        assert_eq!(c.n_clusters, 2, "clusters: {}", c.n_clusters);
        // points of the same blob share a cluster id
        let first_blob = &c.point_cluster[..60];
        let second_blob = &c.point_cluster[60..];
        let id0 = first_blob.iter().find(|&&i| i != usize::MAX).unwrap();
        let id1 = second_blob.iter().find(|&&i| i != usize::MAX).unwrap();
        assert_ne!(id0, id1);
        let same0 = first_blob.iter().filter(|&&i| i == *id0).count();
        assert!(same0 > 50, "blob-0 agreement {same0}");
    }

    #[test]
    fn anomaly_detector_flags_outlier_not_inlier() {
        let x = blobs(80, 2);
        let det =
            AnomalyDetector::train(KnnOptimized::new(5, true), &x, 2, 0.05);
        // an inlier near blob 0
        assert!(!det.is_anomaly(&[0.1, -0.2]));
        // a far outlier
        assert!(det.is_anomaly(&[100.0, -50.0]));
    }

    #[test]
    fn anomaly_false_alarm_rate_bounded() {
        let x = blobs(100, 3);
        let det =
            AnomalyDetector::train(KnnOptimized::new(5, true), &x, 2, 0.1);
        // fresh exchangeable points: alarm rate should be ~<= eps (+fuzz)
        let fresh = blobs(50, 4);
        let alarms = (0..100)
            .filter(|&i| det.is_anomaly(&fresh[i * 2..i * 2 + 2]))
            .count();
        assert!(alarms <= 22, "false alarms {alarms}/100");
    }

    #[test]
    fn pca2_projects_to_dominant_plane() {
        // 5-D data with variance concentrated in dims 0 and 1
        let mut rng = Rng::seed_from(5);
        let n = 200;
        let mut x = vec![0.0; n * 5];
        for i in 0..n {
            x[i * 5] = 10.0 * rng.normal();
            x[i * 5 + 1] = 5.0 * rng.normal();
            for j in 2..5 {
                x[i * 5 + j] = 0.01 * rng.normal();
            }
        }
        let proj = pca2(&x, 5);
        // projected variance ~ original dominant variances
        let var = |k: usize| -> f64 {
            let m: f64 = (0..n).map(|i| proj[i * 2 + k]).sum::<f64>() / n as f64;
            (0..n)
                .map(|i| (proj[i * 2 + k] - m).powi(2))
                .sum::<f64>()
                / n as f64
        };
        assert!(var(0) > 50.0, "pc1 var {}", var(0));
        assert!(var(1) > 10.0, "pc2 var {}", var(1));
    }
}
