//! Kernel Density Estimation nonconformity measure (paper §4):
//!
//!   A((x,y); Z) = - 1/(n_y h^p) * sum_{x_i : y_i = y} K((x - x_i)/h)
//!
//! with the Gaussian kernel K(u) = exp(-||u||^2 / 2) (App. E). The
//! standard variant recomputes the kernel sum on every LOO bag; the
//! optimized variant (§4.1 — the paper's novel incremental&decremental
//! KDE) precomputes preliminary scores
//!
//!   alpha'_i = sum_{j != i : y_j = y_i} K((x_i - x_j)/h)
//!
//! at training time and applies an O(P_K) update per point at prediction
//! time.
//!
//! `n_y` is the number of examples in the *scored example's own bag*
//! carrying its label — for alpha_i that bag is {(x,y)} u Z \ {i}, so
//! n_{y_i} = count(y_i) - 1 + [y == y_i]; both variants derive it the
//! same way, keeping them exactly equal.
//!
//! Numerical stability: the h^p factor is label-independent and constant
//! across all n+1 scores of a p-value computation, so it never changes
//! score ordering; we keep it for fidelity but compute it in log space
//! and skip it when it would under/overflow f64 (p = 784 with h != 1),
//! which is this implementation's replacement for the paper's
//! arbitrary-precision fallback (App. G, DESIGN.md §5).

use crate::cp::icp::IcpMeasure;
use crate::cp::measure::{CpMeasure, Scores};
use crate::data::{Dataset, Label};
use crate::linalg::engine::{native, Engine};

/// 1/h^p scale, or 1.0 when it would leave f64 range (ordering-safe).
fn h_scale(h: f64, p: usize) -> f64 {
    let log = -(p as f64) * h.ln();
    if log.abs() > 600.0 {
        1.0
    } else {
        log.exp()
    }
}

/// Shared final-score formula: alpha = -(1/(n_y h^p)) * ksum.
#[inline]
fn kde_alpha(ksum: f64, n_y: usize, scale: f64) -> f64 {
    if n_y == 0 {
        0.0
    } else {
        -(scale / n_y as f64) * ksum
    }
}

// ---------------------------------------------------------------------
// Standard
// ---------------------------------------------------------------------

/// Standard KDE full-CP measure: O(P_K n^2 l m) prediction.
pub struct KdeStandard {
    pub h: f64,
    ds: Option<Dataset>,
    engine: Engine,
}

impl KdeStandard {
    pub fn new(h: f64) -> Self {
        KdeStandard {
            h,
            ds: None,
            engine: native(),
        }
    }

    pub fn with_engine(h: f64, engine: Engine) -> Self {
        KdeStandard {
            h,
            ds: None,
            engine,
        }
    }
}

impl CpMeasure for KdeStandard {
    fn name(&self) -> String {
        "kde-standard".into()
    }

    fn fit(&mut self, ds: &Dataset) {
        self.ds = Some(ds.clone());
    }

    fn scores(&self, x: &[f64], y: Label) -> Scores {
        let ds = self.ds.as_ref().expect("fit first");
        let n = ds.n();
        let h2 = self.h * self.h;
        let scale = h_scale(self.h, ds.p);
        let counts = ds.label_counts();

        // kernel row for the test point
        let mut k_test = vec![0.0; n];
        self.engine.kde_row(x, &ds.x, ds.p, h2, &mut k_test);

        let mut train = Vec::with_capacity(n);
        let mut k_i = vec![0.0; n];
        for i in 0..n {
            self.engine.kde_row(ds.row(i), &ds.x, ds.p, h2, &mut k_i);
            // sum over the bag {(x,y)} u Z \ {i} restricted to label y_i
            let mut ksum = 0.0;
            for j in 0..n {
                if j != i && ds.y[j] == ds.y[i] {
                    ksum += k_i[j];
                }
            }
            let mut n_y = counts[ds.y[i]] - 1;
            if y == ds.y[i] {
                ksum += k_test[i];
                n_y += 1;
            }
            train.push(kde_alpha(ksum, n_y, scale));
        }

        // test score over bag Z restricted to label y
        let mut ksum = 0.0;
        for j in 0..n {
            if ds.y[j] == y {
                ksum += k_test[j];
            }
        }
        let n_y = if y < counts.len() { counts[y] } else { 0 };
        Scores {
            train,
            test: kde_alpha(ksum, n_y, scale),
        }
    }

    /// Batched standard KDE. The per-pair path recomputes every
    /// training point's kernel row per (x, y) pair; this override
    /// issues exactly two kernel-matrix launches per batch: one
    /// `m x n` matrix for the test rows and one `n x n` matrix for the
    /// training rows' label-restricted preliminary sums. The
    /// preliminary sums accumulate in the same j-order as the per-pair
    /// loop and the matrix entries replay the row kernel exactly, so
    /// all scores are bit-identical to per-pair [`CpMeasure::scores`].
    fn scores_batch(&self, xs: &[&[f64]], labels: &[Label]) -> Vec<Scores> {
        let ds = self.ds.as_ref().expect("fit first");
        let n = ds.n();
        let h2 = self.h * self.h;
        let scale = h_scale(self.h, ds.p);
        let counts = ds.label_counts();
        if xs.is_empty() || labels.is_empty() {
            return Vec::new();
        }
        if n == 0 {
            let score = Scores {
                train: Vec::new(),
                test: kde_alpha(0.0, 0, scale),
            };
            return vec![score; xs.len() * labels.len()];
        }
        // one m x n kernel-matrix launch for every test object's row
        let mut xs_flat = Vec::with_capacity(xs.len() * ds.p);
        for x in xs {
            xs_flat.extend_from_slice(x);
        }
        let mut k_tests = vec![0.0; xs.len() * n];
        self.engine.kde_matrix(&xs_flat, &ds.x, ds.p, h2, &mut k_tests);
        // per-training-point preliminary sums from one n x n launch
        // (the standard baseline is O(n^2) work regardless)
        let k_train_matrix = {
            let mut k = vec![0.0; n * n];
            self.engine.kde_matrix(&ds.x, &ds.x, ds.p, h2, &mut k);
            k
        };
        let mut prelim = vec![0.0; n];
        for (i, k_i) in k_train_matrix.chunks_exact(n).enumerate() {
            let mut s = 0.0;
            for j in 0..n {
                if j != i && ds.y[j] == ds.y[i] {
                    s += k_i[j];
                }
            }
            prelim[i] = s;
        }
        let mut out = Vec::with_capacity(xs.len() * labels.len());
        for k_test in k_tests.chunks_exact(n) {
            for &y in labels {
                let mut train = Vec::with_capacity(n);
                for i in 0..n {
                    let mut ksum = prelim[i];
                    let mut n_y = counts[ds.y[i]] - 1;
                    if y == ds.y[i] {
                        ksum += k_test[i];
                        n_y += 1;
                    }
                    train.push(kde_alpha(ksum, n_y, scale));
                }
                let mut ksum = 0.0;
                for j in 0..n {
                    if ds.y[j] == y {
                        ksum += k_test[j];
                    }
                }
                let n_y = if y < counts.len() { counts[y] } else { 0 };
                out.push(Scores {
                    train,
                    test: kde_alpha(ksum, n_y, scale),
                });
            }
        }
        out
    }

    fn n(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n())
    }

    fn n_labels(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n_labels)
    }
}

// ---------------------------------------------------------------------
// Optimized (§4.1)
// ---------------------------------------------------------------------

/// Optimized KDE full-CP measure: O(P_K n^2) train, O(P_K n l m) predict.
pub struct KdeOptimized {
    pub h: f64,
    ds: Option<Dataset>,
    /// preliminary scores alpha'_i = sum_{j!=i, y_j=y_i} K_ij
    prelim: Vec<f64>,
    counts: Vec<usize>,
    engine: Engine,
}

impl KdeOptimized {
    pub fn new(h: f64) -> Self {
        Self::with_engine(h, native())
    }

    pub fn with_engine(h: f64, engine: Engine) -> Self {
        KdeOptimized {
            h,
            ds: None,
            prelim: Vec::new(),
            counts: Vec::new(),
            engine,
        }
    }

    /// §4.1's O(n) preliminary-score update given a precomputed kernel
    /// row from the test object to every training point. Shared by
    /// `scores` (one row per call) and `scores_batch` (one row reused
    /// across all candidate labels).
    fn scores_from_krow(&self, k_test: &[f64], y: Label) -> Scores {
        let ds = self.ds.as_ref().expect("fit first");
        let n = ds.n();
        let scale = h_scale(self.h, ds.p);
        let mut train = Vec::with_capacity(n);
        let mut test_sum = 0.0;
        for i in 0..n {
            let (ksum, n_y) = if ds.y[i] == y {
                test_sum += k_test[i];
                (self.prelim[i] + k_test[i], self.counts[ds.y[i]])
            } else {
                (self.prelim[i], self.counts[ds.y[i]] - 1)
            };
            train.push(kde_alpha(ksum, n_y, scale));
        }
        let n_y = if y < self.counts.len() {
            self.counts[y]
        } else {
            0
        };
        Scores {
            train,
            test: kde_alpha(test_sum, n_y, scale),
        }
    }
}

impl CpMeasure for KdeOptimized {
    fn name(&self) -> String {
        "kde-optimized".into()
    }

    fn fit(&mut self, ds: &Dataset) {
        let n = ds.n();
        let h2 = self.h * self.h;
        self.ds = Some(ds.clone());
        self.counts = ds.label_counts();
        self.prelim = vec![0.0; n];
        // streamed row-by-row: O(n) memory as in App. D
        let mut k_i = vec![0.0; n];
        for i in 0..n {
            self.engine.kde_row(ds.row(i), &ds.x, ds.p, h2, &mut k_i);
            let mut s = 0.0;
            for j in 0..n {
                if j != i && ds.y[j] == ds.y[i] {
                    s += k_i[j];
                }
            }
            self.prelim[i] = s;
        }
    }

    fn scores(&self, x: &[f64], y: Label) -> Scores {
        let ds = self.ds.as_ref().expect("fit first");
        let h2 = self.h * self.h;
        let mut k_test = vec![0.0; ds.n()];
        self.engine.kde_row(x, &ds.x, ds.p, h2, &mut k_test);
        self.scores_from_krow(&k_test, y)
    }

    /// Batched optimized KDE: ONE `m x n` kernel-matrix launch computes
    /// every test object's Gaussian kernel row, each reused across
    /// every candidate label's §4.1 preliminary-score update.
    /// Bit-identical to per-pair [`CpMeasure::scores`]: the matrix
    /// entries replay the row kernel exactly and both paths share
    /// [`Self::scores_from_krow`].
    fn scores_batch(&self, xs: &[&[f64]], labels: &[Label]) -> Vec<Scores> {
        let ds = self.ds.as_ref().expect("fit first");
        let n = ds.n();
        let h2 = self.h * self.h;
        if xs.is_empty() || labels.is_empty() {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(xs.len() * labels.len());
        if n == 0 {
            for _ in xs {
                for &y in labels {
                    out.push(self.scores_from_krow(&[], y));
                }
            }
            return out;
        }
        let mut xs_flat = Vec::with_capacity(xs.len() * ds.p);
        for x in xs {
            xs_flat.extend_from_slice(x);
        }
        let mut k_tests = vec![0.0; xs.len() * n];
        self.engine.kde_matrix(&xs_flat, &ds.x, ds.p, h2, &mut k_tests);
        for k_test in k_tests.chunks_exact(n) {
            for &y in labels {
                out.push(self.scores_from_krow(k_test, y));
            }
        }
        out
    }

    fn n(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n())
    }

    fn n_labels(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n_labels)
    }

    /// Online increment: O(P_K n).
    fn learn(&mut self, x: &[f64], y: Label) -> bool {
        let Some(ds) = self.ds.as_mut() else {
            return false;
        };
        let h2 = self.h * self.h;
        let n = ds.n();
        let mut k = vec![0.0; n];
        self.engine.kde_row(x, &ds.x, ds.p, h2, &mut k);
        let mut own = 0.0;
        for i in 0..n {
            if ds.y[i] == y {
                self.prelim[i] += k[i];
                own += k[i];
            }
        }
        self.prelim.push(own);
        ds.push(x, y);
        if y >= self.counts.len() {
            self.counts.resize(y + 1, 0);
        }
        self.counts[y] += 1;
        true
    }

    /// Online decrement: O(P_K n).
    fn unlearn(&mut self, idx: usize) -> bool {
        let Some(ds) = self.ds.as_mut() else {
            return false;
        };
        if idx >= ds.n() {
            return false;
        }
        let h2 = self.h * self.h;
        let n = ds.n();
        let x_rm = ds.row(idx).to_vec();
        let y_rm = ds.y[idx];
        let mut k = vec![0.0; n];
        self.engine.kde_row(&x_rm, &ds.x, ds.p, h2, &mut k);
        for i in 0..n {
            if i != idx && ds.y[i] == y_rm {
                self.prelim[i] -= k[i];
            }
        }
        self.prelim.remove(idx);
        self.counts[y_rm] -= 1;
        ds.remove(idx);
        true
    }
}

// ---------------------------------------------------------------------
// ICP
// ---------------------------------------------------------------------

/// Inductive KDE measure.
pub struct IcpKde {
    pub h: f64,
    proper: Option<Dataset>,
    counts: Vec<usize>,
    engine: Engine,
}

impl IcpKde {
    pub fn new(h: f64) -> Self {
        IcpKde {
            h,
            proper: None,
            counts: Vec::new(),
            engine: native(),
        }
    }
}

impl IcpMeasure for IcpKde {
    fn name(&self) -> String {
        "icp-kde".into()
    }

    fn fit(&mut self, proper: &Dataset) {
        self.counts = proper.label_counts();
        self.proper = Some(proper.clone());
    }

    fn score(&self, x: &[f64], y: Label) -> f64 {
        let ds = self.proper.as_ref().expect("fit first");
        let h2 = self.h * self.h;
        let scale = h_scale(self.h, ds.p);
        let mut k = vec![0.0; ds.n()];
        self.engine.kde_row(x, &ds.x, ds.p, h2, &mut k);
        // EXACT-ALLOW: EXACT001 ICP scoring sums the kernel row in
        // fixed index order on every engine; the engines only change
        // how k[j] is produced, never this reduction order.
        let ksum: f64 = (0..ds.n())
            .filter(|&j| ds.y[j] == y)
            .map(|j| k[j])
            .sum();
        let n_y = if y < self.counts.len() {
            self.counts[y]
        } else {
            0
        };
        kde_alpha(ksum, n_y, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::pvalue::p_value;
    use crate::data::{make_classification, ClassificationSpec};

    fn small_ds(n: usize, seed: u64) -> Dataset {
        make_classification(
            &ClassificationSpec {
                n_samples: n,
                n_features: 6,
                n_informative: 3,
                n_redundant: 1,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn optimized_matches_standard() {
        let ds = small_ds(35, 1);
        let mut s = KdeStandard::new(1.0);
        let mut o = KdeOptimized::new(1.0);
        s.fit(&ds);
        o.fit(&ds);
        let probe = small_ds(8, 2);
        for i in 0..probe.n() {
            for y in 0..2 {
                let a = s.scores(probe.row(i), y);
                let b = o.scores(probe.row(i), y);
                for (u, v) in a.train.iter().zip(&b.train) {
                    assert!((u - v).abs() < 1e-10, "{u} vs {v}");
                }
                assert!((a.test - b.test).abs() < 1e-10);
                assert_eq!(p_value(&a), p_value(&b));
            }
        }
    }

    #[test]
    fn bandwidth_affects_scores() {
        let ds = small_ds(20, 3);
        let mut narrow = KdeOptimized::new(0.2);
        let mut wide = KdeOptimized::new(5.0);
        narrow.fit(&ds);
        wide.fit(&ds);
        let a = narrow.scores(ds.row(0), ds.y[0]);
        let b = wide.scores(ds.row(0), ds.y[0]);
        assert!(a.test != b.test);
    }

    #[test]
    fn learn_then_unlearn_roundtrip() {
        let ds = small_ds(25, 4);
        let mut m = KdeOptimized::new(1.0);
        m.fit(&ds);
        let before: Vec<f64> = m.prelim.clone();
        let x_new = vec![0.5; 6];
        assert!(m.learn(&x_new, 1));
        assert!(m.unlearn(25)); // remove the point just added
        for (a, b) in m.prelim.iter().zip(&before) {
            assert!((a - b).abs() < 1e-9);
        }
        assert_eq!(m.n(), 25);
    }

    #[test]
    fn learn_matches_refit() {
        let ds = small_ds(20, 5);
        let extra = small_ds(5, 6);
        let mut inc = KdeOptimized::new(1.0);
        inc.fit(&ds);
        let mut grown = ds.clone();
        for i in 0..extra.n() {
            inc.learn(extra.row(i), extra.y[i]);
            grown.push(extra.row(i), extra.y[i]);
        }
        let mut refit = KdeOptimized::new(1.0);
        refit.fit(&grown);
        let q = small_ds(3, 7);
        for i in 0..q.n() {
            for y in 0..2 {
                let a = inc.scores(q.row(i), y);
                let b = refit.scores(q.row(i), y);
                for (u, v) in a.train.iter().zip(&b.train) {
                    assert!((u - v).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn high_dim_does_not_produce_nan() {
        // p=784-style: kernel values underflow to 0, but scores must
        // remain finite (log-space h_scale guard).
        let mut x = vec![0.0; 200 * 784];
        for (i, v) in x.iter_mut().enumerate() {
            *v = ((i * 2654435761) % 1000) as f64 / 1000.0;
        }
        let ds = Dataset::new(x, (0..200).map(|i| i % 10).collect(), 784, 10);
        let mut m = KdeOptimized::new(1.0);
        m.fit(&ds);
        let s = m.scores(ds.row(0), 0);
        assert!(s.train.iter().all(|v| v.is_finite()));
        assert!(s.test.is_finite());
    }

    #[test]
    fn scores_batch_bit_identical_to_single() {
        let ds = small_ds(28, 9);
        let probe = small_ds(5, 10);
        let xs: Vec<&[f64]> = (0..probe.n()).map(|i| probe.row(i)).collect();
        let mut s = KdeStandard::new(0.8);
        let mut o = KdeOptimized::new(0.8);
        s.fit(&ds);
        o.fit(&ds);
        for m in [&s as &dyn CpMeasure, &o as &dyn CpMeasure] {
            let batch = m.scores_batch(&xs, &[0, 1]);
            assert_eq!(batch.len(), xs.len() * 2);
            for (xi, x) in xs.iter().enumerate() {
                for y in 0..2usize {
                    let single = m.scores(x, y);
                    let got = &batch[xi * 2 + y];
                    assert_eq!(got.test.to_bits(), single.test.to_bits());
                    for (a, b) in got.train.iter().zip(&single.train) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
            }
            assert!(m.scores_batch(&[], &[0, 1]).is_empty());
            assert!(m.scores_batch(&xs, &[]).is_empty());
        }
    }

    #[test]
    fn icp_kde_prefers_own_label() {
        let ds = small_ds(60, 8);
        let mut icp = IcpKde::new(1.0);
        icp.fit(&ds);
        // centroid-ish point of class 0
        let i0 = (0..ds.n()).find(|&i| ds.y[i] == 0).unwrap();
        let s_own = icp.score(ds.row(i0), 0);
        let s_other = icp.score(ds.row(i0), 1);
        assert!(s_own < s_other, "{s_own} vs {s_other}");
    }
}
