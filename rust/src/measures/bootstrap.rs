//! Bootstrap (Random-Forest) nonconformity measure (paper §6, App. B.2).
//!
//! Standard variant: for every LOO bag, draw B bootstrap samples, train
//! B base classifiers, score — O((T_g(n) + P_g(1)) B n l m). Ruinously
//! expensive; kept for fidelity and used in the benches at small n.
//!
//! Optimized variant — Algorithm 3: augment Z with a placeholder "*" for
//! the not-yet-seen test point, keep drawing bootstrap samples of Z*
//! until every example (and "*") is *excluded* from at least B samples;
//! classifiers for samples without "*" are pre-trained at training time
//! and their votes for each training point pre-counted, so prediction
//! only trains the (shared!) classifiers whose sample contains "*" once
//! the test point is known. This achieves the paper's
//! (1 - e^-1) ~ 0.632 prediction-time factor; unlike the other measures
//! it is *not* exact w.r.t. the standard variant (Table 1: x) — it is
//! the same estimator family under a different sampling coupling, so
//! tests assert validity/behaviour rather than score equality.

use crate::cp::icp::IcpMeasure;
use crate::cp::measure::{CpMeasure, Scores};
use crate::data::{Dataset, Label, Rng};
use crate::measures::tree::{DecisionTree, TreeParams};

/// Hyperparameters shared by the bootstrap variants.
#[derive(Clone, Debug)]
pub struct BootstrapParams {
    /// ensemble size B (paper App. E: 10)
    pub b: usize,
    pub tree: TreeParams,
    pub seed: u64,
}

impl Default for BootstrapParams {
    fn default() -> Self {
        BootstrapParams {
            b: 10,
            tree: TreeParams::default(),
            seed: 0,
        }
    }
}

fn draw_sample(n: usize, rng: &mut Rng) -> Vec<usize> {
    (0..n).map(|_| rng.below(n)).collect()
}

/// -f^y(x): negative normalized vote count of the ensemble.
fn vote_score(trees: &[DecisionTree], x: &[f64], y: Label) -> f64 {
    let votes = trees.iter().filter(|t| t.predict(x) == y).count();
    -(votes as f64) / trees.len() as f64
}

// ---------------------------------------------------------------------
// Standard
// ---------------------------------------------------------------------

/// Standard bootstrap full-CP measure (retrain-everything baseline).
pub struct BootstrapStandard {
    pub params: BootstrapParams,
    ds: Option<Dataset>,
}

impl BootstrapStandard {
    pub fn new(params: BootstrapParams) -> Self {
        BootstrapStandard { params, ds: None }
    }

    /// Train a fresh B-ensemble on `bag` and score (x, y) against it.
    fn ensemble_score(
        &self,
        bag: &Dataset,
        x: &[f64],
        y: Label,
        rng: &mut Rng,
    ) -> f64 {
        let trees: Vec<DecisionTree> = (0..self.params.b)
            .map(|_| {
                let idx = draw_sample(bag.n(), rng);
                DecisionTree::fit_indices(bag, &idx, &self.params.tree, rng)
            })
            .collect();
        vote_score(&trees, x, y)
    }
}

impl CpMeasure for BootstrapStandard {
    fn name(&self) -> String {
        "rf-standard".into()
    }

    fn fit(&mut self, ds: &Dataset) {
        self.ds = Some(ds.clone());
    }

    fn scores(&self, x: &[f64], y: Label) -> Scores {
        let ds = self.ds.as_ref().expect("fit first");
        let n = ds.n();
        // Deterministic per-(x,y) stream so repeated calls agree.
        let mut rng = Rng::seed_from(
            self.params.seed ^ x.iter().map(|v| v.to_bits()).fold(y as u64, u64::wrapping_add),
        );
        // augmented set Z u {(x,y)}
        let mut aug = ds.clone();
        aug.push(x, y);
        let mut train = Vec::with_capacity(n);
        let mut keep: Vec<usize> = Vec::with_capacity(n);
        for i in 0..n {
            keep.clear();
            keep.extend((0..=n).filter(|&j| j != i));
            let bag = aug.subset(&keep);
            train.push(self.ensemble_score(&bag, ds.row(i), ds.y[i], &mut rng));
        }
        let test = self.ensemble_score(ds, x, y, &mut rng);
        Scores { train, test }
    }

    fn n(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n())
    }

    fn n_labels(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n_labels)
    }
}

// ---------------------------------------------------------------------
// Optimized (Algorithm 3)
// ---------------------------------------------------------------------

/// One bootstrap sample of Z* = Z u {*}; index `n` encodes "*".
struct StarSample {
    /// drawn indices into Z* (values in 0..=n; n means "*")
    idx: Vec<usize>,
    /// classifier pre-trained at fit time (samples without "*")
    pretrained: Option<DecisionTree>,
}

/// Optimized bootstrap full-CP measure (Algorithm 3).
pub struct BootstrapOptimized {
    pub params: BootstrapParams,
    ds: Option<Dataset>,
    samples: Vec<StarSample>,
    /// per training point: sample ids whose bootstrap EXCLUDES it
    /// (truncated to B, the paper's footnote 1)
    e_i: Vec<Vec<usize>>,
    /// sample ids excluding "*" (the test ensemble E)
    e_star: Vec<usize>,
    /// per training point: votes for y_i already counted from
    /// pretrained members of E_i
    pre_votes: Vec<usize>,
    /// per training point: members of E_i that contain "*" (deferred)
    pending: Vec<Vec<usize>>,
    /// actual number of samples drawn (the paper's B')
    pub b_prime: usize,
}

impl BootstrapOptimized {
    pub fn new(params: BootstrapParams) -> Self {
        BootstrapOptimized {
            params,
            ds: None,
            samples: Vec::new(),
            e_i: Vec::new(),
            e_star: Vec::new(),
            pre_votes: Vec::new(),
            pending: Vec::new(),
            b_prime: 0,
        }
    }
}

impl CpMeasure for BootstrapOptimized {
    fn name(&self) -> String {
        "rf-optimized".into()
    }

    /// TRAIN() of Algorithm 3.
    fn fit(&mut self, ds: &Dataset) {
        let n = ds.n();
        let b = self.params.b;
        let mut rng = Rng::seed_from(self.params.seed);
        self.ds = Some(ds.clone());
        self.samples.clear();
        self.e_i = vec![Vec::new(); n];
        self.e_star.clear();

        // Draw samples of Z* until every example and "*" have >= B
        // excluding-samples.
        let mut contains = vec![false; n + 1];
        let mut deficit = n + 1; // how many points still lack B samples
        let mut have = vec![0usize; n + 1];
        while deficit > 0 {
            let idx = draw_sample(n + 1, &mut rng);
            let sid = self.samples.len();
            for c in contains.iter_mut() {
                *c = false;
            }
            for &j in &idx {
                contains[j] = true;
            }
            for j in 0..=n {
                if !contains[j] && have[j] < b {
                    have[j] += 1;
                    if have[j] == b {
                        deficit -= 1;
                    }
                    if j < n {
                        self.e_i[j].push(sid);
                    } else {
                        self.e_star.push(sid);
                    }
                }
            }
            self.samples.push(StarSample {
                idx,
                pretrained: None,
            });
        }
        self.b_prime = self.samples.len();

        // Pre-train classifiers for samples not containing "*", i.e.
        // usable without knowing the test point.
        for s in self.samples.iter_mut() {
            if !s.idx.contains(&n) {
                let tree =
                    DecisionTree::fit_indices(ds, &s.idx, &self.params.tree, &mut rng);
                s.pretrained = Some(tree);
            }
        }

        // Pre-count votes for each training point from its pretrained
        // ensemble members; defer the "*"-containing ones.
        self.pre_votes = vec![0; n];
        self.pending = vec![Vec::new(); n];
        for i in 0..n {
            for &sid in &self.e_i[i] {
                match &self.samples[sid].pretrained {
                    Some(tree) => {
                        if tree.predict(ds.row(i)) == ds.y[i] {
                            self.pre_votes[i] += 1;
                        }
                    }
                    None => self.pending[i].push(sid),
                }
            }
        }
    }

    /// COMPUTE_PVALUE() of Algorithm 3 (scores part).
    fn scores(&self, x: &[f64], y: Label) -> Scores {
        let ds = self.ds.as_ref().expect("fit first");
        let n = ds.n();
        let b = self.params.b as f64;
        let mut rng = Rng::seed_from(
            self.params.seed
                ^ x.iter().map(|v| v.to_bits()).fold(y as u64, u64::wrapping_add),
        );

        // Train the deferred classifiers once per *sample* (shared
        // across all training points whose E_i references them —
        // App. C.4's "Remark" on why the effective cost is B', not Bn).
        let mut star_trees: std::collections::HashMap<usize, DecisionTree> =
            std::collections::HashMap::new();
        let mut aug = ds.clone();
        aug.push(x, y);
        let needed: std::collections::BTreeSet<usize> = self
            .pending
            .iter()
            .flat_map(|v| v.iter().copied())
            .collect();
        for sid in needed {
            let tree = DecisionTree::fit_indices(
                &aug,
                &self.samples[sid].idx, // index n now resolves to (x, y)
                &self.params.tree,
                &mut rng,
            );
            star_trees.insert(sid, tree);
        }

        let mut train = Vec::with_capacity(n);
        for i in 0..n {
            let mut votes = self.pre_votes[i];
            for sid in &self.pending[i] {
                if star_trees[sid].predict(ds.row(i)) == ds.y[i] {
                    votes += 1;
                }
            }
            train.push(-(votes as f64) / b);
        }

        // test score from ensemble E (all pretrained by construction)
        let votes = self
            .e_star
            .iter()
            .filter(|&&sid| {
                self.samples[sid]
                    .pretrained
                    .as_ref()
                    .expect("E samples exclude *")
                    .predict(x)
                    == y
            })
            .count();
        Scores {
            train,
            test: -(votes as f64) / b,
        }
    }

    fn n(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n())
    }

    fn n_labels(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n_labels)
    }
}

// ---------------------------------------------------------------------
// ICP
// ---------------------------------------------------------------------

/// Inductive Random-Forest measure: one ensemble on the proper set.
pub struct IcpRandomForest {
    pub params: BootstrapParams,
    trees: Vec<DecisionTree>,
}

impl IcpRandomForest {
    pub fn new(params: BootstrapParams) -> Self {
        IcpRandomForest {
            params,
            trees: Vec::new(),
        }
    }
}

impl IcpMeasure for IcpRandomForest {
    fn name(&self) -> String {
        "icp-rf".into()
    }

    fn fit(&mut self, proper: &Dataset) {
        let mut rng = Rng::seed_from(self.params.seed);
        self.trees = (0..self.params.b)
            .map(|_| {
                let idx = draw_sample(proper.n(), &mut rng);
                DecisionTree::fit_indices(proper, &idx, &self.params.tree, &mut rng)
            })
            .collect();
    }

    fn score(&self, x: &[f64], y: Label) -> f64 {
        vote_score(&self.trees, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::classifier::FullCp;
    use crate::data::{make_classification, ClassificationSpec};

    fn ds(n: usize, seed: u64) -> Dataset {
        make_classification(
            &ClassificationSpec {
                n_samples: n,
                n_features: 6,
                n_informative: 3,
                n_redundant: 1,
                class_sep: 2.0,
                flip_y: 0.0,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn optimized_every_point_has_b_samples() {
        let d = ds(30, 1);
        let mut m = BootstrapOptimized::new(BootstrapParams {
            b: 5,
            ..Default::default()
        });
        m.fit(&d);
        assert!(m.b_prime >= 5);
        for e in &m.e_i {
            assert_eq!(e.len(), 5, "every point must get exactly B samples");
        }
        assert_eq!(m.e_star.len(), 5);
        // E_i samples must exclude i; E samples must exclude *
        for (i, e) in m.e_i.iter().enumerate() {
            for &sid in e {
                assert!(!m.samples[sid].idx.contains(&i));
            }
        }
        for &sid in &m.e_star {
            assert!(!m.samples[sid].idx.contains(&d.n()));
            assert!(m.samples[sid].pretrained.is_some());
        }
    }

    #[test]
    fn scores_are_valid_vote_fractions() {
        let d = ds(25, 2);
        let mut m = BootstrapOptimized::new(BootstrapParams::default());
        m.fit(&d);
        let s = m.scores(d.row(0), 0);
        assert_eq!(s.train.len(), 25);
        for &a in s.train.iter().chain(std::iter::once(&s.test)) {
            assert!((-1.0..=0.0).contains(&a), "score {a}");
            // multiples of 1/B
            let scaled = -a * m.params.b as f64;
            assert!((scaled - scaled.round()).abs() < 1e-9);
        }
    }

    #[test]
    fn optimized_p_values_favor_true_label() {
        // train and probe must share one generating distribution
        let all = ds(80, 3);
        let mut rng = Rng::seed_from(40);
        let (d, probe) = all.split(60, &mut rng);
        let cp = FullCp::train(
            BootstrapOptimized::new(BootstrapParams::default()),
            &d,
        );
        // average p-value of true label should exceed that of the other
        let (mut p_true, mut p_false) = (0.0, 0.0);
        for i in 0..probe.n() {
            let ps = cp.p_values(probe.row(i));
            p_true += ps[probe.y[i]];
            p_false += ps[1 - probe.y[i]];
        }
        assert!(
            p_true > p_false,
            "true-label p mass {p_true} vs {p_false}"
        );
    }

    #[test]
    fn standard_scores_shape() {
        let d = ds(10, 5);
        let mut m = BootstrapStandard::new(BootstrapParams {
            b: 3,
            ..Default::default()
        });
        m.fit(&d);
        let s = m.scores(d.row(0), 1);
        assert_eq!(s.train.len(), 10);
        assert!(s.train.iter().all(|a| (-1.0..=0.0).contains(a)));
    }

    #[test]
    fn b_prime_grows_with_n() {
        // Figure 5: B' needed grows with n (rarer to exclude any fixed
        // point as samples grow... actually P(exclude) ~ e^-1, but the
        // max over n+1 points needs more draws as n grows).
        let d_small = ds(10, 6);
        let d_large = ds(80, 6);
        let mut a = BootstrapOptimized::new(BootstrapParams::default());
        let mut b = BootstrapOptimized::new(BootstrapParams::default());
        a.fit(&d_small);
        b.fit(&d_large);
        assert!(b.b_prime >= a.b_prime, "{} vs {}", b.b_prime, a.b_prime);
    }

    #[test]
    fn icp_rf_scores() {
        let d = ds(80, 7);
        let mut m = IcpRandomForest::new(BootstrapParams::default());
        m.fit(&d);
        let s_own = m.score(d.row(0), d.y[0]);
        assert!((-1.0..=0.0).contains(&s_own));
    }
}
