//! Nonconformity measures — every method the paper studies (§3–§6),
//! each in a *standard* (from-scratch LOO) and an *optimized*
//! (incremental&decremental) variant, plus the ICP version used as the
//! computational baseline.

pub mod bootstrap;
pub mod kde;
pub mod knn;
pub mod lssvm;
pub mod tree;

pub use bootstrap::{
    BootstrapOptimized, BootstrapParams, BootstrapStandard, IcpRandomForest,
};
pub use kde::{IcpKde, KdeOptimized, KdeStandard};
pub use knn::{IcpKnn, KnnOptimized, KnnStandard};
pub use lssvm::{FeatureMap, IcpLsSvm, LsSvmModel, LsSvmOptimized, LsSvmStandard};
pub use tree::{DecisionTree, TreeParams};
