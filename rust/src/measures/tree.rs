//! Decision-tree substrate for the bootstrap / Random-Forest measure
//! (§6). CART-style: Gini impurity, depth limit, sqrt(p) feature
//! subsampling per split — matching the paper's App. E Random Forest
//! configuration (depth <= 10, sqrt(p) features per split).

use crate::data::{Dataset, Label, Rng};

/// One tree node (flat arena representation).
#[derive(Clone, Debug)]
enum Node {
    Leaf {
        label: Label,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// Decision-tree hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// number of features examined per split (0 = sqrt(p))
    pub max_features: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        // paper App. E: depth up to 10, sqrt(p) features
        TreeParams {
            max_depth: 10,
            min_samples_split: 2,
            max_features: 0,
        }
    }
}

/// A trained classification tree.
#[derive(Clone, Debug)]
pub struct DecisionTree {
    nodes: Vec<Node>,
    pub n_labels: usize,
}

impl DecisionTree {
    /// Fit on the rows of `ds` selected by `idx` (with repetition —
    /// bootstrap samples pass their multiset of indices directly).
    pub fn fit_indices(
        ds: &Dataset,
        idx: &[usize],
        params: &TreeParams,
        rng: &mut Rng,
    ) -> Self {
        assert!(!idx.is_empty());
        let max_features = if params.max_features == 0 {
            ((ds.p as f64).sqrt().round() as usize).clamp(1, ds.p)
        } else {
            params.max_features.min(ds.p)
        };
        let mut tree = DecisionTree {
            nodes: Vec::new(),
            n_labels: ds.n_labels,
        };
        let mut work = idx.to_vec();
        tree.build(ds, &mut work, 0, params, max_features, rng);
        tree
    }

    /// Fit on a whole dataset.
    pub fn fit(ds: &Dataset, params: &TreeParams, rng: &mut Rng) -> Self {
        let idx: Vec<usize> = (0..ds.n()).collect();
        Self::fit_indices(ds, &idx, params, rng)
    }

    fn majority(ds: &Dataset, idx: &[usize], n_labels: usize) -> Label {
        let mut counts = vec![0usize; n_labels];
        for &i in idx {
            counts[ds.y[i]] += 1;
        }
        counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(l, _)| l)
            .unwrap_or(0)
    }

    fn gini_from_counts(counts: &[usize], total: usize) -> f64 {
        if total == 0 {
            return 0.0;
        }
        let t = total as f64;
        1.0 - counts
            .iter()
            .map(|&c| {
                let f = c as f64 / t;
                f * f
            })
            .sum::<f64>()
    }

    /// Recursively build; `idx` is the working set for this subtree.
    fn build(
        &mut self,
        ds: &Dataset,
        idx: &mut [usize],
        depth: usize,
        params: &TreeParams,
        max_features: usize,
        rng: &mut Rng,
    ) -> usize {
        let n_labels = ds.n_labels;
        // stopping conditions
        let pure = {
            let first = ds.y[idx[0]];
            idx.iter().all(|&i| ds.y[i] == first)
        };
        if pure || depth >= params.max_depth || idx.len() < params.min_samples_split
        {
            let node = Node::Leaf {
                label: Self::majority(ds, idx, n_labels),
            };
            self.nodes.push(node);
            return self.nodes.len() - 1;
        }

        // candidate features
        let feats = rng.sample_indices(ds.p, max_features);
        let mut best: Option<(f64, usize, f64)> = None; // (gini, feat, thr)
        let mut parent_counts = vec![0usize; n_labels];
        for &i in idx.iter() {
            parent_counts[ds.y[i]] += 1;
        }
        let mut vals: Vec<(f64, Label)> = Vec::with_capacity(idx.len());
        for &f in &feats {
            vals.clear();
            vals.extend(idx.iter().map(|&i| (ds.row(i)[f], ds.y[i])));
            vals.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
            // sweep thresholds between distinct values
            let mut left_counts = vec![0usize; n_labels];
            let total = idx.len();
            for s in 0..total - 1 {
                left_counts[vals[s].1] += 1;
                if vals[s].0 == vals[s + 1].0 {
                    continue;
                }
                let nl = s + 1;
                let nr = total - nl;
                let right_counts: Vec<usize> = parent_counts
                    .iter()
                    .zip(&left_counts)
                    .map(|(p, l)| p - l)
                    .collect();
                let g = (nl as f64 * Self::gini_from_counts(&left_counts, nl)
                    + nr as f64 * Self::gini_from_counts(&right_counts, nr))
                    / total as f64;
                let thr = 0.5 * (vals[s].0 + vals[s + 1].0);
                if best.map_or(true, |(bg, _, _)| g < bg) {
                    best = Some((g, f, thr));
                }
            }
        }

        let Some((_, feature, threshold)) = best else {
            // no valid split (all candidate features constant)
            let node = Node::Leaf {
                label: Self::majority(ds, idx, n_labels),
            };
            self.nodes.push(node);
            return self.nodes.len() - 1;
        };

        // partition in place
        let mut split = 0usize;
        for i in 0..idx.len() {
            if ds.row(idx[i])[feature] <= threshold {
                idx.swap(i, split);
                split += 1;
            }
        }
        if split == 0 || split == idx.len() {
            let node = Node::Leaf {
                label: Self::majority(ds, idx, n_labels),
            };
            self.nodes.push(node);
            return self.nodes.len() - 1;
        }

        // placeholder, patched after children are built
        self.nodes.push(Node::Leaf { label: 0 });
        let me = self.nodes.len() - 1;
        let (l_idx, r_idx) = idx.split_at_mut(split);
        let left = self.build(ds, l_idx, depth + 1, params, max_features, rng);
        let right = self.build(ds, r_idx, depth + 1, params, max_features, rng);
        self.nodes[me] = Node::Split {
            feature,
            threshold,
            left,
            right,
        };
        me
    }

    /// Predicted label for `x`. Note the arena root is the FIRST node
    /// pushed by the outermost `build` call for leaves, but a patched
    /// placeholder for splits — both are found at the index returned by
    /// that call, which is 0 only for a leaf-only tree; we track it by
    /// convention: the outer `build` always returns the root index, and
    /// `fit*` call it with an empty arena, so root == first Leaf OR the
    /// placeholder pushed before children — i.e. index 0 in both cases
    /// is wrong for splits. We therefore search from the stored root.
    pub fn predict(&self, x: &[f64]) -> Label {
        let mut cur = self.root();
        loop {
            match &self.nodes[cur] {
                Node::Leaf { label } => return *label,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    cur = if x[*feature] <= *threshold {
                        *left
                    } else {
                        *right
                    };
                }
            }
        }
    }

    #[inline]
    fn root(&self) -> usize {
        // The root is the first node pushed by the outer build() call:
        // for a leaf root that is index 0; for a split root the
        // placeholder is also pushed before any child, hence index 0.
        0
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_classification, ClassificationSpec};

    fn ds(n: usize, seed: u64) -> Dataset {
        make_classification(
            &ClassificationSpec {
                n_samples: n,
                n_features: 8,
                n_informative: 4,
                n_redundant: 2,
                class_sep: 2.0,
                flip_y: 0.0,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn learns_separable_data() {
        let d = ds(300, 1);
        let mut rng = Rng::seed_from(2);
        let tree = DecisionTree::fit(
            &d,
            &TreeParams {
                max_features: 8, // all features: should nail it
                ..Default::default()
            },
            &mut rng,
        );
        let correct = (0..d.n())
            .filter(|&i| tree.predict(d.row(i)) == d.y[i])
            .count();
        let acc = correct as f64 / d.n() as f64;
        assert!(acc > 0.9, "train accuracy {acc}");
    }

    #[test]
    fn respects_depth_limit() {
        let d = ds(200, 3);
        let mut rng = Rng::seed_from(4);
        let stump = DecisionTree::fit(
            &d,
            &TreeParams {
                max_depth: 1,
                ..Default::default()
            },
            &mut rng,
        );
        // depth-1 tree has at most 3 nodes
        assert!(stump.n_nodes() <= 3, "{}", stump.n_nodes());
    }

    #[test]
    fn handles_constant_features() {
        let d = Dataset::new(vec![1.0; 20], vec![0, 1, 0, 1, 0, 1, 0, 1, 0, 1], 2, 2);
        let mut rng = Rng::seed_from(5);
        let tree = DecisionTree::fit(&d, &TreeParams::default(), &mut rng);
        // degenerates to majority leaf, never panics
        let _ = tree.predict(&[1.0, 1.0]);
    }

    #[test]
    fn pure_node_short_circuits() {
        let d = Dataset::new(vec![0., 0., 1., 1., 2., 2.], vec![1, 1, 1], 2, 2);
        let mut rng = Rng::seed_from(6);
        let tree = DecisionTree::fit(&d, &TreeParams::default(), &mut rng);
        assert_eq!(tree.n_nodes(), 1);
        assert_eq!(tree.predict(&[5.0, 5.0]), 1);
    }

    #[test]
    fn bootstrap_indices_fit() {
        let d = ds(100, 7);
        let mut rng = Rng::seed_from(8);
        let idx: Vec<usize> = (0..100).map(|_| rng.below(100)).collect();
        let tree = DecisionTree::fit_indices(&d, &idx, &TreeParams::default(), &mut rng);
        let _ = tree.predict(d.row(0));
    }
}
