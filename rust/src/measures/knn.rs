//! Nearest-neighbour nonconformity measures (paper §3): *k-NN* (Eq. 2)
//! and *Simplified k-NN*, each in a standard O(n^2 l m) variant and the
//! paper's optimized O(n l m) incremental&decremental variant (§3.1).
//!
//! Edge-case conventions (shared by standard and optimized variants so
//! the exactness tests hold bit-for-bit):
//!
//! * a k-NN sum over an *empty* candidate set is +inf (no support for
//!   the label -> maximally nonconforming);
//! * with fewer than k candidates, the sum runs over what exists (and
//!   the incoming test point simply joins the set, evicting nothing);
//! * the k-NN ratio with a zero denominator is +inf unless the
//!   numerator is zero too (duplicate points on both sides), which is
//!   1.0; empty-num/empty-den is 1.0 (no information).

use crate::cp::measure::{CpMeasure, Scores};
use crate::cp::icp::IcpMeasure;
use crate::data::{Dataset, Label};
use crate::linalg::engine::{native, Engine};
use crate::linalg::select::KBest;

/// Sum semantics for a possibly-underfull neighbour set.
#[inline]
fn knn_sum(len: usize, sum: f64) -> f64 {
    if len == 0 {
        f64::INFINITY
    } else {
        sum
    }
}

/// Ratio semantics for the full k-NN measure (Eq. 2).
#[inline]
fn knn_ratio(num_len: usize, num: f64, den_len: usize, den: f64) -> f64 {
    match (num_len == 0, den_len == 0) {
        (true, true) => 1.0,
        (true, false) => f64::INFINITY,
        (false, true) => 0.0,
        (false, false) => {
            if den == 0.0 {
                if num == 0.0 {
                    1.0
                } else {
                    f64::INFINITY
                }
            } else {
                num / den
            }
        }
    }
}

/// Sum of the k smallest same-/different-label distances from `x` to
/// the training set, streamed without allocating per-label vectors.
fn kbest_split(
    d: &[f64],
    ys: &[Label],
    skip: Option<usize>,
    label: Label,
    k: usize,
) -> (KBest, KBest) {
    let mut same = KBest::new(k);
    let mut diff = KBest::new(k);
    for (j, (&dj, &yj)) in d.iter().zip(ys).enumerate() {
        if Some(j) == skip {
            continue;
        }
        if yj == label {
            same.insert(dj);
        } else {
            diff.insert(dj);
        }
    }
    (same, diff)
}

// ---------------------------------------------------------------------
// Standard variants — recompute the measure from scratch on every LOO
// bag, exactly the paper's baseline (Table 1 "Standard").
// ---------------------------------------------------------------------

/// Standard (Simplified) k-NN full-CP measure.
pub struct KnnStandard {
    pub k: usize,
    /// Simplified k-NN keeps only the same-label numerator.
    pub simplified: bool,
    ds: Option<Dataset>,
    engine: Engine,
}

impl KnnStandard {
    pub fn new(k: usize, simplified: bool) -> Self {
        KnnStandard {
            k,
            simplified,
            ds: None,
            engine: native(),
        }
    }

    pub fn with_engine(k: usize, simplified: bool, engine: Engine) -> Self {
        KnnStandard {
            k,
            simplified,
            ds: None,
            engine,
        }
    }

    fn ds(&self) -> &Dataset {
        self.ds.as_ref().expect("fit() before scores()")
    }

    /// A((q, label); bag) where the bag is rows of `ds` minus `skip`,
    /// plus optionally the test point at distance `d_test`.
    fn measure_on_bag(
        &self,
        d_row: &[f64],
        ys: &[Label],
        skip: Option<usize>,
        label: Label,
        extra: Option<(f64, Label)>,
    ) -> f64 {
        let (mut same, mut diff) = kbest_split(d_row, ys, skip, label, self.k);
        if let Some((d, y)) = extra {
            if y == label {
                same.insert(d);
            } else {
                diff.insert(d);
            }
        }
        let num = knn_sum(same.len(), same.sum());
        if self.simplified {
            num
        } else {
            knn_ratio(same.len(), same.sum(), diff.len(), diff.sum())
        }
    }
}

impl CpMeasure for KnnStandard {
    fn name(&self) -> String {
        format!(
            "{}-standard",
            if self.simplified { "simplified-knn" } else { "knn" }
        )
    }

    fn fit(&mut self, ds: &Dataset) {
        self.ds = Some(ds.clone());
    }

    fn scores(&self, x: &[f64], y: Label) -> Scores {
        let ds = self.ds();
        let n = ds.n();
        let p = ds.p;
        let mut d_test = vec![0.0; n];
        self.engine.dist_row_sq(x, &ds.x, p, &mut d_test);
        for v in d_test.iter_mut() {
            *v = v.sqrt();
        }
        let mut train = Vec::with_capacity(n);
        let mut d_i = vec![0.0; n];
        for i in 0..n {
            // Distances from x_i to every training point; the bag for
            // alpha_i excludes i itself and includes the test example.
            self.engine.dist_row_sq(ds.row(i), &ds.x, p, &mut d_i);
            for v in d_i.iter_mut() {
                *v = v.sqrt();
            }
            let alpha = self.measure_on_bag(
                &d_i,
                &ds.y,
                Some(i),
                ds.y[i],
                Some((d_test[i], y)),
            );
            train.push(alpha);
        }
        let test = self.measure_on_bag(&d_test, &ds.y, None, y, None);
        Scores { train, test }
    }

    /// Batched standard scoring. The per-pair path recomputes every
    /// training point's distance row for every (x, y) pair — m·l·(n+1)
    /// O(n p) rows for an m-object, l-label batch; this override issues
    /// exactly two matrix launches per batch: one `m x n` test matrix
    /// and one `n x n` pairwise training matrix, reused across all
    /// pairs. Scores are bit-identical to per-pair [`CpMeasure::scores`]
    /// because the tiled kernel's entries replay `sq_dist` exactly, so
    /// every `measure_on_bag` call receives the same inputs.
    fn scores_batch(&self, xs: &[&[f64]], labels: &[Label]) -> Vec<Scores> {
        let ds = self.ds();
        let n = ds.n();
        let p = ds.p;
        if xs.is_empty() || labels.is_empty() {
            return Vec::new();
        }
        if n == 0 {
            let mut out = Vec::with_capacity(xs.len() * labels.len());
            for _ in xs {
                for &y in labels {
                    out.push(Scores {
                        train: Vec::new(),
                        test: self.measure_on_bag(&[], &ds.y, None, y, None),
                    });
                }
            }
            return out;
        }
        // one m x n matrix launch covers every test object's distance row
        let mut xs_flat = Vec::with_capacity(xs.len() * p);
        for x in xs {
            xs_flat.extend_from_slice(x);
        }
        let mut d_tests = vec![0.0; xs.len() * n];
        self.engine.dist_matrix_sq(&xs_flat, &ds.x, p, &mut d_tests);
        for v in d_tests.iter_mut() {
            *v = v.sqrt();
        }
        // test scores up front; train slots filled by the i-sweep below
        let mut out = Vec::with_capacity(xs.len() * labels.len());
        for d_test in d_tests.chunks_exact(n) {
            for &y in labels {
                out.push(Scores {
                    train: vec![0.0; n],
                    test: self.measure_on_bag(d_test, &ds.y, None, y, None),
                });
            }
        }
        // every training point's distance row in one n x n launch (the
        // standard baseline is O(n^2) work regardless; materializing the
        // matrix trades O(n^2) memory for one launch per batch), reused
        // across every (test object, label) pair
        let mut d_train = self.engine.pairwise_sq(&ds.x, p);
        for v in d_train.iter_mut() {
            *v = v.sqrt();
        }
        for (i, d_i) in d_train.chunks_exact(n).enumerate() {
            for (xi, d_test) in d_tests.chunks_exact(n).enumerate() {
                for (li, &y) in labels.iter().enumerate() {
                    out[xi * labels.len() + li].train[i] = self
                        .measure_on_bag(
                            d_i,
                            &ds.y,
                            Some(i),
                            ds.y[i],
                            Some((d_test[i], y)),
                        );
                }
            }
        }
        out
    }

    fn n(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n())
    }

    fn n_labels(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n_labels)
    }
}

// ---------------------------------------------------------------------
// Optimized variants — §3.1: precompute per-point k-best structures in
// the training phase; prediction-phase updates are O(1) per point.
// ---------------------------------------------------------------------

/// Optimized (Simplified) k-NN full-CP measure, incremental&decremental.
pub struct KnnOptimized {
    pub k: usize,
    pub simplified: bool,
    ds: Option<Dataset>,
    /// per-point k best same-label distances (Delta_i^1..Delta_i^k)
    same: Vec<KBest>,
    /// per-point k best different-label distances (full k-NN only)
    diff: Vec<KBest>,
    engine: Engine,
}

impl KnnOptimized {
    pub fn new(k: usize, simplified: bool) -> Self {
        Self::with_engine(k, simplified, native())
    }

    pub fn with_engine(k: usize, simplified: bool, engine: Engine) -> Self {
        KnnOptimized {
            k,
            simplified,
            ds: None,
            same: Vec::new(),
            diff: Vec::new(),
            engine,
        }
    }

    fn ds(&self) -> &Dataset {
        self.ds.as_ref().expect("fit() before scores()")
    }

    /// Rebuild row i's k-best structures from scratch (unlearn path).
    fn rebuild_row(&mut self, i: usize) {
        let ds = self.ds.as_ref().unwrap();
        let mut d = vec![0.0; ds.n()];
        self.engine.dist_row_sq(ds.row(i), &ds.x, ds.p, &mut d);
        for v in d.iter_mut() {
            *v = v.sqrt();
        }
        let (same, diff) = kbest_split(&d, &ds.y, Some(i), ds.y[i], self.k);
        self.same[i] = same;
        self.diff[i] = diff;
    }

    /// §3.1's provisional-score sweep given a precomputed (already
    /// square-rooted) distance row `d` from the test object to every
    /// training point. Shared by `scores` (one row per call) and
    /// `scores_batch` (one row reused across all candidate labels).
    fn scores_from_row(&self, d: &[f64], y: Label) -> Scores {
        let ds = self.ds();
        let n = ds.n();

        // alpha for the test example: k best same-label (and diff-label)
        // distances from x to Z.
        let (t_same, t_diff) = kbest_split(d, &ds.y, None, y, self.k);

        let mut train = Vec::with_capacity(n);
        if self.simplified {
            for i in 0..n {
                let kb = &self.same[i];
                let alpha = if ds.y[i] == y {
                    // test point may enter i's same-label k-NN set
                    let len = if kb.full() { kb.len() } else { kb.len() + 1 };
                    knn_sum(len, kb.sum_with(d[i]))
                } else {
                    knn_sum(kb.len(), kb.sum())
                };
                train.push(alpha);
            }
            Scores {
                train,
                test: knn_sum(t_same.len(), t_same.sum()),
            }
        } else {
            for i in 0..n {
                let (s, f) = (&self.same[i], &self.diff[i]);
                let (ns_len, ns_sum, nd_len, nd_sum) = if ds.y[i] == y {
                    let len = if s.full() { s.len() } else { s.len() + 1 };
                    (len, s.sum_with(d[i]), f.len(), f.sum())
                } else {
                    let len = if f.full() { f.len() } else { f.len() + 1 };
                    (s.len(), s.sum(), len, f.sum_with(d[i]))
                };
                train.push(knn_ratio(ns_len, ns_sum, nd_len, nd_sum));
            }
            Scores {
                train,
                test: knn_ratio(
                    t_same.len(),
                    t_same.sum(),
                    t_diff.len(),
                    t_diff.sum(),
                ),
            }
        }
    }
}

impl CpMeasure for KnnOptimized {
    fn name(&self) -> String {
        format!(
            "{}-optimized",
            if self.simplified { "simplified-knn" } else { "knn" }
        )
    }

    /// Training phase: O(n^2 p) distance work, O(n k) memory (App. D) —
    /// the pairwise matrix is streamed, never materialized. §Perf: on
    /// the native engine each distance is computed once (upper triangle)
    /// and inserted into both endpoints' k-best sets — a measured ~2x
    /// over the row-per-point formulation; non-native engines (PJRT)
    /// keep the row kernel, which is what they accelerate.
    fn fit(&mut self, ds: &Dataset) {
        let n = ds.n();
        self.ds = Some(ds.clone());
        self.same = (0..n).map(|_| KBest::new(self.k)).collect();
        self.diff = (0..n).map(|_| KBest::new(self.k)).collect();
        if self.engine.name() == "native" {
            for i in 0..n {
                let ri = ds.row(i);
                for j in i + 1..n {
                    let d =
                        crate::linalg::distance::sq_dist(ri, ds.row(j)).sqrt();
                    if ds.y[i] == ds.y[j] {
                        self.same[i].insert(d);
                        self.same[j].insert(d);
                    } else {
                        self.diff[i].insert(d);
                        self.diff[j].insert(d);
                    }
                }
            }
        } else {
            let mut d = vec![0.0; n];
            for i in 0..n {
                self.engine.dist_row_sq(ds.row(i), &ds.x, ds.p, &mut d);
                for v in d.iter_mut() {
                    *v = v.sqrt();
                }
                let (same, diff) =
                    kbest_split(&d, &ds.y, Some(i), ds.y[i], self.k);
                self.same[i] = same;
                self.diff[i] = diff;
            }
        }
    }

    /// Prediction phase: one O(n p) distance row, then O(1) per-point
    /// provisional-score updates (Figure 1's rule).
    fn scores(&self, x: &[f64], y: Label) -> Scores {
        let ds = self.ds();
        let mut d = vec![0.0; ds.n()];
        self.engine.dist_row_sq(x, &ds.x, ds.p, &mut d);
        for v in d.iter_mut() {
            *v = v.sqrt();
        }
        self.scores_from_row(&d, y)
    }

    /// One `scores_batch` over `xs × labels`: ONE `m x n` matrix launch
    /// computes every test object's distance row, each reused across
    /// every candidate label's provisional-score sweep (vs one row
    /// kernel per (x, y) pair in the per-pair path). Bit-identical to
    /// per-pair [`CpMeasure::scores`] by construction: the tiled kernel
    /// replays `sq_dist` per entry and both paths share
    /// [`Self::scores_from_row`].
    fn scores_batch(&self, xs: &[&[f64]], labels: &[Label]) -> Vec<Scores> {
        let ds = self.ds();
        let n = ds.n();
        if xs.is_empty() || labels.is_empty() {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(xs.len() * labels.len());
        if n == 0 {
            for _ in xs {
                for &y in labels {
                    out.push(self.scores_from_row(&[], y));
                }
            }
            return out;
        }
        let mut xs_flat = Vec::with_capacity(xs.len() * ds.p);
        for x in xs {
            xs_flat.extend_from_slice(x);
        }
        let mut d = vec![0.0; xs.len() * n];
        self.engine.dist_matrix_sq(&xs_flat, &ds.x, ds.p, &mut d);
        for v in d.iter_mut() {
            *v = v.sqrt();
        }
        for row in d.chunks_exact(n) {
            for &y in labels {
                out.push(self.scores_from_row(row, y));
            }
        }
        out
    }

    fn n(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n())
    }

    fn n_labels(&self) -> usize {
        self.ds.as_ref().map_or(0, |d| d.n_labels)
    }

    /// Online increment (§9): O(n p) — one distance row + O(k) inserts.
    fn learn(&mut self, x: &[f64], y: Label) -> bool {
        let Some(ds) = self.ds.as_mut() else {
            return false;
        };
        let n = ds.n();
        let p = ds.p;
        let mut d = vec![0.0; n];
        self.engine.dist_row_sq(x, &ds.x, p, &mut d);
        for v in d.iter_mut() {
            *v = v.sqrt();
        }
        // update existing rows
        for i in 0..n {
            if ds.y[i] == y {
                self.same[i].insert(d[i]);
            } else {
                self.diff[i].insert(d[i]);
            }
        }
        // build the new row
        let (same, diff) = kbest_split(&d, &ds.y, None, y, self.k);
        self.same.push(same);
        self.diff.push(diff);
        ds.push(x, y);
        true
    }

    /// Online decrement: remove training index `idx`; rows whose k-best
    /// sets could contain the removed point are rebuilt.
    fn unlearn(&mut self, idx: usize) -> bool {
        let Some(ds) = self.ds.as_mut() else {
            return false;
        };
        if idx >= ds.n() {
            return false;
        }
        let (x_rm, y_rm) = (ds.row(idx).to_vec(), ds.y[idx]);
        // distances from the removed point to everyone (to test k-best
        // membership cheaply)
        let mut d = vec![0.0; ds.n()];
        self.engine.dist_row_sq(&x_rm, &ds.x, ds.p, &mut d);
        for v in d.iter_mut() {
            *v = v.sqrt();
        }
        ds.remove(idx);
        self.same.remove(idx);
        self.diff.remove(idx);
        // note: d still indexed by OLD indices; map old j -> new row
        let stale: Vec<usize> = (0..d.len())
            .filter(|&j| j != idx)
            .filter(|&j| {
                let new_j = if j > idx { j - 1 } else { j };
                let kb = if self.ds.as_ref().unwrap().y[new_j] == y_rm {
                    &self.same[new_j]
                } else {
                    &self.diff[new_j]
                };
                // candidate was possibly among j's k best
                d[j] <= kb.max() || !kb.full()
            })
            .map(|j| if j > idx { j - 1 } else { j })
            .collect();
        for i in stale {
            self.rebuild_row(i);
        }
        true
    }
}

// ---------------------------------------------------------------------
// ICP variant
// ---------------------------------------------------------------------

/// Inductive (k-NN / Simplified k-NN) measure: scores against the proper
/// training set only.
pub struct IcpKnn {
    pub k: usize,
    pub simplified: bool,
    proper: Option<Dataset>,
    engine: Engine,
}

impl IcpKnn {
    pub fn new(k: usize, simplified: bool) -> Self {
        IcpKnn {
            k,
            simplified,
            proper: None,
            engine: native(),
        }
    }
}

impl IcpMeasure for IcpKnn {
    fn name(&self) -> String {
        format!(
            "icp-{}",
            if self.simplified { "simplified-knn" } else { "knn" }
        )
    }

    fn fit(&mut self, proper: &Dataset) {
        self.proper = Some(proper.clone());
    }

    fn score(&self, x: &[f64], y: Label) -> f64 {
        let ds = self.proper.as_ref().expect("fit first");
        let mut d = vec![0.0; ds.n()];
        self.engine.dist_row_sq(x, &ds.x, ds.p, &mut d);
        for v in d.iter_mut() {
            *v = v.sqrt();
        }
        let (same, diff) = kbest_split(&d, &ds.y, None, y, self.k);
        if self.simplified {
            knn_sum(same.len(), same.sum())
        } else {
            knn_ratio(same.len(), same.sum(), diff.len(), diff.sum())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::pvalue::p_value;
    use crate::data::{make_classification, ClassificationSpec};

    fn small_ds(n: usize, seed: u64) -> Dataset {
        make_classification(
            &ClassificationSpec {
                n_samples: n,
                n_features: 5,
                n_informative: 3,
                n_redundant: 1,
                ..Default::default()
            },
            seed,
        )
    }

    fn assert_scores_match(a: &Scores, b: &Scores) {
        assert_eq!(a.train.len(), b.train.len());
        for (i, (x, y)) in a.train.iter().zip(&b.train).enumerate() {
            let ok = (x - y).abs() <= 1e-9 * (1.0 + x.abs())
                || (x.is_infinite() && y.is_infinite());
            assert!(ok, "train[{i}]: {x} vs {y}");
        }
        let ok = (a.test - b.test).abs() <= 1e-9 * (1.0 + a.test.abs())
            || (a.test.is_infinite() && b.test.is_infinite());
        assert!(ok, "test: {} vs {}", a.test, b.test);
    }

    #[test]
    fn optimized_matches_standard_simplified() {
        let ds = small_ds(40, 1);
        let mut std_m = KnnStandard::new(3, true);
        let mut opt_m = KnnOptimized::new(3, true);
        std_m.fit(&ds);
        opt_m.fit(&ds);
        let probe = small_ds(10, 2);
        for i in 0..probe.n() {
            for y in 0..2 {
                let a = std_m.scores(probe.row(i), y);
                let b = opt_m.scores(probe.row(i), y);
                assert_scores_match(&a, &b);
                assert_eq!(p_value(&a), p_value(&b));
            }
        }
    }

    #[test]
    fn optimized_matches_standard_full_knn() {
        let ds = small_ds(40, 3);
        let mut std_m = KnnStandard::new(5, false);
        let mut opt_m = KnnOptimized::new(5, false);
        std_m.fit(&ds);
        opt_m.fit(&ds);
        let probe = small_ds(10, 4);
        for i in 0..probe.n() {
            for y in 0..2 {
                let a = std_m.scores(probe.row(i), y);
                let b = opt_m.scores(probe.row(i), y);
                assert_scores_match(&a, &b);
            }
        }
    }

    #[test]
    fn k_larger_than_class_counts() {
        // k = 15 > class size: exercises under-full KBest paths
        let ds = small_ds(12, 5);
        let mut std_m = KnnStandard::new(15, true);
        let mut opt_m = KnnOptimized::new(15, true);
        std_m.fit(&ds);
        opt_m.fit(&ds);
        let x = ds.row(0).to_vec();
        for y in 0..2 {
            assert_scores_match(&std_m.scores(&x, y), &opt_m.scores(&x, y));
        }
    }

    #[test]
    fn duplicate_points_and_ties() {
        // exact duplicates across labels: zero distances everywhere
        let x = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 2.0, 2.0];
        let ds = Dataset::new(x, vec![0, 1, 0, 1], 2, 2);
        let mut std_m = KnnStandard::new(2, false);
        let mut opt_m = KnnOptimized::new(2, false);
        std_m.fit(&ds);
        opt_m.fit(&ds);
        for y in 0..2 {
            let a = std_m.scores(&[1.0, 1.0], y);
            let b = opt_m.scores(&[1.0, 1.0], y);
            assert_scores_match(&a, &b);
        }
    }

    #[test]
    fn learn_matches_refit() {
        let ds = small_ds(30, 7);
        let probe = small_ds(5, 8);
        // incrementally learned
        let mut inc = KnnOptimized::new(3, true);
        inc.fit(&ds);
        let mut grown = ds.clone();
        for i in 0..probe.n() {
            assert!(inc.learn(probe.row(i), probe.y[i]));
            grown.push(probe.row(i), probe.y[i]);
        }
        // refit from scratch
        let mut refit = KnnOptimized::new(3, true);
        refit.fit(&grown);
        let q = small_ds(3, 9);
        for i in 0..q.n() {
            for y in 0..2 {
                assert_scores_match(
                    &inc.scores(q.row(i), y),
                    &refit.scores(q.row(i), y),
                );
            }
        }
    }

    #[test]
    fn unlearn_matches_refit() {
        let ds = small_ds(30, 10);
        let mut dec = KnnOptimized::new(3, false);
        dec.fit(&ds);
        assert!(dec.unlearn(7));
        assert!(dec.unlearn(0));
        let mut shrunk = ds.clone();
        shrunk.remove(7);
        shrunk.remove(0);
        let mut refit = KnnOptimized::new(3, false);
        refit.fit(&shrunk);
        let q = small_ds(3, 11);
        for i in 0..q.n() {
            for y in 0..2 {
                assert_scores_match(
                    &dec.scores(q.row(i), y),
                    &refit.scores(q.row(i), y),
                );
            }
        }
    }

    #[test]
    fn scores_batch_bit_identical_to_single() {
        let ds = small_ds(30, 20);
        let probe = small_ds(6, 21);
        let xs: Vec<&[f64]> = (0..probe.n()).map(|i| probe.row(i)).collect();
        for simplified in [true, false] {
            let mut std_m = KnnStandard::new(3, simplified);
            let mut opt_m = KnnOptimized::new(3, simplified);
            std_m.fit(&ds);
            opt_m.fit(&ds);
            for m in [&std_m as &dyn CpMeasure, &opt_m as &dyn CpMeasure] {
                let batch = m.scores_batch(&xs, &[0, 1]);
                assert_eq!(batch.len(), xs.len() * 2);
                for (xi, x) in xs.iter().enumerate() {
                    for y in 0..2usize {
                        let single = m.scores(x, y);
                        let got = &batch[xi * 2 + y];
                        assert_eq!(got.test.to_bits(), single.test.to_bits());
                        assert_eq!(got.train.len(), single.train.len());
                        for (a, b) in got.train.iter().zip(&single.train) {
                            assert_eq!(a.to_bits(), b.to_bits());
                        }
                    }
                }
                assert!(m.scores_batch(&[], &[0, 1]).is_empty());
                assert!(m.scores_batch(&xs, &[]).is_empty());
            }
        }
    }

    #[test]
    fn icp_knn_scores_sane() {
        let ds = small_ds(30, 12);
        let mut icp = IcpKnn::new(3, true);
        icp.fit(&ds);
        // a training point scores low for its own label
        let a_own = icp.score(ds.row(0), ds.y[0]);
        let a_other = icp.score(ds.row(0), 1 - ds.y[0]);
        assert!(a_own.is_finite());
        assert!(a_own < a_other || a_other.is_infinite());
    }
}
