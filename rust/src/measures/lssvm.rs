//! Kernel LS-SVM nonconformity measure (paper §5, App. B.1).
//!
//! The LS-SVM regressor f(x) = w^T phi(x) is trained with ridge
//! regularization; the measure is A((x,y); Z) = -y f(x) for binary
//! labels y in {-1, +1}. "Kernel" LS-SVM is realized through explicit
//! finite feature maps phi: X -> R^q (linear, and random Fourier
//! features approximating the Gaussian kernel), which is exactly the
//! setting of Lee et al. (2019)'s O(q^3) exact incremental&decremental
//! updates the paper builds on.
//!
//! * Standard variant: retrains the closed form on every LOO bag —
//!   O(n^(w+1) l m) prediction (Table 1).
//! * Optimized variant (§5.1): trains once (O(n q^2 + q^3)), stores the
//!   auxiliary matrix C = Phi [Phi^T Phi + rho I]^-1 Phi^T, then per
//!   test candidate performs ONE incremental add of the test example
//!   (O(q^2)) followed by a *virtual decrement* per training example:
//!   only the updated w is needed to score (x_i, y_i), so each LOO step
//!   is O(q^2) with no O(q^3) matrix work and no mutation — an
//!   implementation-level sharpening of the paper's O(q^3 n l m) bound
//!   that leaves the algorithm (and its outputs) identical.
//!
//! Training uses the push-through identity
//!   w = Phi [Phi^T Phi + rho I_n]^-1 Y = [Phi Phi^T + rho I_q]^-1 Phi Y,
//!   C = Phi [Phi^T Phi + rho I_n]^-1 Phi^T = [Phi Phi^T + rho I_q]^-1 Phi Phi^T,
//! so the factorization is q x q instead of n x n.

use crate::cp::icp::IcpMeasure;
use crate::cp::measure::{CpMeasure, Scores};
use crate::data::{Dataset, Label, Rng};
use crate::linalg::{self, dot, Mat};

/// Explicit feature map.
#[derive(Clone, Debug)]
pub enum FeatureMap {
    /// phi(x) = x (linear kernel; q = p). The paper's §7 configuration.
    Linear,
    /// Random Fourier features approximating the Gaussian kernel with
    /// bandwidth `gamma`: phi(x) = sqrt(2/q) cos(W x + b).
    Rff {
        q: usize,
        gamma: f64,
        seed: u64,
    },
}

impl FeatureMap {
    pub fn dim(&self, p: usize) -> usize {
        match self {
            FeatureMap::Linear => p,
            FeatureMap::Rff { q, .. } => *q,
        }
    }

    /// Materialize the map for input dimension `p`.
    pub fn build(&self, p: usize) -> BuiltMap {
        match self {
            FeatureMap::Linear => BuiltMap::Linear,
            FeatureMap::Rff { q, gamma, seed } => {
                let mut rng = Rng::seed_from(*seed);
                let scale = (2.0 * gamma).sqrt();
                let w: Vec<f64> =
                    (0..q * p).map(|_| rng.normal() * scale).collect();
                let b: Vec<f64> = (0..*q)
                    .map(|_| rng.f64() * 2.0 * std::f64::consts::PI)
                    .collect();
                BuiltMap::Rff {
                    w,
                    b,
                    p,
                    q: *q,
                    norm: (2.0 / *q as f64).sqrt(),
                }
            }
        }
    }
}

/// A feature map bound to a concrete input dimension.
#[derive(Clone, Debug)]
pub enum BuiltMap {
    Linear,
    Rff {
        w: Vec<f64>,
        b: Vec<f64>,
        p: usize,
        q: usize,
        norm: f64,
    },
}

impl BuiltMap {
    pub fn apply(&self, x: &[f64], out: &mut Vec<f64>) {
        out.clear();
        match self {
            BuiltMap::Linear => out.extend_from_slice(x),
            BuiltMap::Rff { w, b, p, q, norm } => {
                debug_assert_eq!(x.len(), *p);
                for i in 0..*q {
                    let z = dot(&w[i * p..(i + 1) * p], x) + b[i];
                    out.push(norm * z.cos());
                }
            }
        }
    }
}

/// Trained LS-SVM state: weight vector + Lee et al. auxiliary matrix.
#[derive(Clone, Debug)]
pub struct LsSvmModel {
    pub w: Vec<f64>,
    pub c: Mat,
    pub rho: f64,
}

impl LsSvmModel {
    /// Closed-form ridge training over featurized rows `phi` (n x q).
    pub fn train(phi: &Mat, ys: &[f64], rho: f64) -> Self {
        // G = Phi Phi^T + rho I_q  (q x q; Phi columns are examples, so
        // with row-major per-example storage this is phi^T phi + rho I)
        let mut g = phi.gram();
        g.add_diag(rho);
        let ginv = linalg::spd_inverse(&g).expect("ridge Gram must be SPD");
        // w = G^-1 Phi^T Y ; Phi^T Y = sum_i y_i phi_i
        let pty = phi.tmatvec(ys);
        let w = ginv.matvec(&pty);
        // C = G^-1 (Phi^T Phi) = G^-1 (G - rho I) = I - rho G^-1
        let mut c = ginv;
        for v in c.data.iter_mut() {
            *v = -*v * rho;
        }
        c.add_diag(1.0);
        LsSvmModel { w, c, rho }
    }

    /// f(x) in feature space.
    #[inline]
    pub fn predict_phi(&self, phi: &[f64]) -> f64 {
        dot(&self.w, phi)
    }

    /// Exact incremental add of one example (Lee et al. 2019): O(q^2).
    pub fn learn(&mut self, phi: &[f64], y: f64) {
        let q = phi.len();
        let mut cphi = self.c.matvec(phi);
        // u = (C - I) phi
        for (i, u) in cphi.iter_mut().enumerate() {
            *u -= phi[i];
        }
        let u = cphi;
        let ptp = dot(phi, phi);
        let ptcp = dot(phi, &u) + ptp; // phi^T C phi, since u = C phi - phi
        let denom = ptp + self.rho - ptcp;
        let resid = dot(phi, &self.w) - y;
        let coef = resid / denom;
        for i in 0..q {
            self.w[i] += u[i] * coef;
        }
        self.c.rank1_update(1.0 / denom, &u, &u);
    }

    /// Exact decremental removal of one example: O(q^2).
    pub fn unlearn(&mut self, phi: &[f64], y: f64) {
        let q = self.w.len();
        let mut u = self.c.matvec(phi);
        for (i, v) in u.iter_mut().enumerate() {
            *v -= phi[i];
        }
        let ptp = dot(phi, phi);
        let ptcp = dot(phi, &u) + ptp;
        let denom = -ptp + self.rho + ptcp;
        let resid = dot(phi, &self.w) - y;
        let coef = resid / denom;
        for i in 0..q {
            self.w[i] -= u[i] * coef;
        }
        self.c.rank1_update(-1.0 / denom, &u, &u);
    }

    /// The weight vector after *virtually* removing (phi, y): O(q^2),
    /// no state mutation, no C update — all that's needed to score one
    /// LOO example.
    pub fn w_without(&self, phi: &[f64], y: f64, w_out: &mut Vec<f64>) {
        let mut u = self.c.matvec(phi);
        for (i, v) in u.iter_mut().enumerate() {
            *v -= phi[i];
        }
        let ptp = dot(phi, phi);
        let ptcp = dot(phi, &u) + ptp;
        let denom = -ptp + self.rho + ptcp;
        let resid = dot(phi, &self.w) - y;
        let coef = resid / denom;
        w_out.clear();
        w_out.extend(self.w.iter().zip(&u).map(|(w, u)| w - u * coef));
    }
}

/// Map a class label {0, 1} to the LS-SVM target {-1, +1}.
#[inline]
fn target(y: Label) -> f64 {
    if y == 0 {
        -1.0
    } else {
        1.0
    }
}

/// Featurize a dataset into an n x q matrix.
fn featurize(map: &BuiltMap, ds: &Dataset) -> Mat {
    let q = match map {
        BuiltMap::Linear => ds.p,
        BuiltMap::Rff { q, .. } => *q,
    };
    let mut m = Mat::zeros(ds.n(), q);
    let mut buf = Vec::with_capacity(q);
    for i in 0..ds.n() {
        map.apply(ds.row(i), &mut buf);
        m.row_mut(i).copy_from_slice(&buf);
    }
    m
}

// ---------------------------------------------------------------------
// Standard
// ---------------------------------------------------------------------

/// Standard LS-SVM full-CP measure: full retrain per LOO bag.
pub struct LsSvmStandard {
    pub rho: f64,
    pub map: FeatureMap,
    built: Option<BuiltMap>,
    phi: Option<Mat>,
    ys: Vec<f64>,
    n_labels: usize,
}

impl LsSvmStandard {
    pub fn new(rho: f64, map: FeatureMap) -> Self {
        LsSvmStandard {
            rho,
            map,
            built: None,
            phi: None,
            ys: Vec::new(),
            n_labels: 0,
        }
    }
}

impl CpMeasure for LsSvmStandard {
    fn name(&self) -> String {
        "lssvm-standard".into()
    }

    fn fit(&mut self, ds: &Dataset) {
        assert_eq!(ds.n_labels, 2, "LS-SVM CP is binary (use one-vs-rest)");
        let built = self.map.build(ds.p);
        self.phi = Some(featurize(&built, ds));
        self.built = Some(built);
        self.ys = ds.y.iter().map(|&l| target(l)).collect();
        self.n_labels = ds.n_labels;
    }

    fn scores(&self, x: &[f64], y: Label) -> Scores {
        let phi = self.phi.as_ref().expect("fit first");
        let built = self.built.as_ref().unwrap();
        let n = phi.rows;
        let q = phi.cols;
        let mut phix = Vec::with_capacity(q);
        built.apply(x, &mut phix);
        let y_t = target(y);

        // augmented feature matrix: Z u {(x,y)}
        let mut aug = Mat::zeros(n + 1, q);
        aug.data[..n * q].copy_from_slice(&phi.data);
        aug.row_mut(n).copy_from_slice(&phix);
        let mut ys_aug = self.ys.clone();
        ys_aug.push(y_t);

        // LOO retrains: bag = aug \ {i}
        let mut train = Vec::with_capacity(n);
        for i in 0..n {
            let mut bag = Mat::zeros(n, q);
            let mut ys = Vec::with_capacity(n);
            let mut r = 0;
            for j in 0..=n {
                if j == i {
                    continue;
                }
                bag.row_mut(r).copy_from_slice(aug.row(j));
                ys.push(ys_aug[j]);
                r += 1;
            }
            let model = LsSvmModel::train(&bag, &ys, self.rho);
            train.push(-self.ys[i] * model.predict_phi(phi.row(i)));
        }
        // test score: model trained on Z
        let model = LsSvmModel::train(phi, &self.ys, self.rho);
        Scores {
            train,
            test: -y_t * model.predict_phi(&phix),
        }
    }

    fn n(&self) -> usize {
        self.phi.as_ref().map_or(0, |m| m.rows)
    }

    fn n_labels(&self) -> usize {
        self.n_labels
    }
}

// ---------------------------------------------------------------------
// Optimized (§5.1)
// ---------------------------------------------------------------------

/// Optimized LS-SVM full-CP measure via Lee et al. (2019) updates.
///
/// §Perf: beyond the paper's O(q^3)->O(q^2)-per-point structure, the LOO
/// sweep here is O(q) per training point: with the per-point scalars
/// ptp_i = phi_i^T phi_i and pcp_i = phi_i^T C phi_i cached at fit time,
/// the virtually-decremented score after the rank-1 test-point update
/// needs only two O(q) dot products per example (see `scores`); the
/// whole sweep is O(n q) — measured ~9x over the direct
/// w_without-per-point formulation (EXPERIMENTS.md §Perf).
pub struct LsSvmOptimized {
    pub rho: f64,
    pub map: FeatureMap,
    built: Option<BuiltMap>,
    phi: Option<Mat>,
    ys: Vec<f64>,
    model: Option<LsSvmModel>,
    /// phi_i^T phi_i per training point
    ptp: Vec<f64>,
    /// phi_i^T C phi_i per training point (maintained under learn/unlearn)
    pcp: Vec<f64>,
    n_labels: usize,
}

impl LsSvmOptimized {
    pub fn new(rho: f64, map: FeatureMap) -> Self {
        LsSvmOptimized {
            rho,
            map,
            built: None,
            phi: None,
            ys: Vec::new(),
            model: None,
            ptp: Vec::new(),
            pcp: Vec::new(),
            n_labels: 0,
        }
    }

    /// Recompute the per-point scalar caches from the current model.
    fn refresh_caches(&mut self) {
        let (Some(phi), Some(model)) = (self.phi.as_ref(), self.model.as_ref())
        else {
            return;
        };
        let n = phi.rows;
        self.ptp = (0..n).map(|i| dot(phi.row(i), phi.row(i))).collect();
        self.pcp = (0..n)
            .map(|i| {
                let cphi = model.c.matvec(phi.row(i));
                dot(phi.row(i), &cphi)
            })
            .collect();
    }

    /// All label-independent state for scoring one test object: the
    /// feature map application (O(q p)), the rank-1 update vector
    /// u = (C - I) phi_x with its O(q^2) matvec, and the per-point
    /// projections b_i = u . phi_i (O(n q)). Computing this once per
    /// test object is what `scores_batch` amortizes across candidate
    /// labels — only the O(n q) virtual-decrement sweep of
    /// [`Self::scores_from_prepared`] remains per label.
    fn prepare_test(&self, x: &[f64]) -> PreparedTest {
        let phi = self.phi.as_ref().expect("fit first");
        let built = self.built.as_ref().unwrap();
        let model = self.model.as_ref().unwrap();
        let mut phix = Vec::with_capacity(phi.cols);
        built.apply(x, &mut phix);
        // Rank-1 state of the augmented model (C_aug = C + u u^T/denom):
        // never materialized — all downstream quantities use u directly.
        let mut u = model.c.matvec(&phix);
        for (ui, &pi) in u.iter_mut().zip(&phix) {
            *ui -= pi;
        }
        let ptp_t = dot(&phix, &phix);
        let ptcp_t = dot(&phix, &u) + ptp_t;
        let denom_t = ptp_t + self.rho - ptcp_t;
        // f(x) on Z and the residual base share one dot product (IEEE
        // multiply commutes bitwise, so dot(w, phix) == dot(phix, w)).
        let wdot = dot(&phix, &model.w);
        let bs: Vec<f64> = (0..phi.rows).map(|i| dot(&u, phi.row(i))).collect();
        PreparedTest {
            u,
            denom_t,
            wdot,
            bs,
        }
    }

    /// Batched [`Self::prepare_test`]: the whole batch's label-independent
    /// state from three matrix launches instead of 3m vector launches —
    /// `U = Px C - Px` (one `m x q` [`linalg::dot_matrix`]; IEEE multiply
    /// commutes bitwise, so `dot(phix, c_row) == dot(c_row, phix)` and
    /// each row equals `prepare_test`'s `C phix - phix` exactly),
    /// `wdots = Px w` (one matvec), and `B = U Phi^T` (one `m x n`
    /// dot-matrix: the per-point projections `b_i`). Every scalar is the
    /// same operation sequence as `prepare_test`, so the prepared states
    /// are bit-identical.
    fn prepare_tests(&self, xs: &[&[f64]]) -> Vec<PreparedTest> {
        let phi = self.phi.as_ref().expect("fit first");
        let built = self.built.as_ref().unwrap();
        let model = self.model.as_ref().unwrap();
        let q = phi.cols;
        let mut px = Mat::zeros(xs.len(), q);
        let mut buf = Vec::with_capacity(q);
        for (r, x) in xs.iter().enumerate() {
            built.apply(x, &mut buf);
            px.row_mut(r).copy_from_slice(&buf);
        }
        let mut u_mat = linalg::dot_matrix(&px, &model.c);
        for r in 0..xs.len() {
            let (urow, prow) = (u_mat.row_mut(r), &px.data[r * q..(r + 1) * q]);
            for (ui, &pi) in urow.iter_mut().zip(prow) {
                *ui -= pi;
            }
        }
        let wdots = px.matvec(&model.w);
        let b_mat = linalg::dot_matrix(&u_mat, phi);
        (0..xs.len())
            .map(|r| {
                let phix = px.row(r);
                let u = u_mat.row(r).to_vec();
                let ptp_t = dot(phix, phix);
                let ptcp_t = dot(phix, &u) + ptp_t;
                let denom_t = ptp_t + self.rho - ptcp_t;
                PreparedTest {
                    u,
                    denom_t,
                    wdot: wdots[r],
                    bs: b_mat.row(r).to_vec(),
                }
            })
            .collect()
    }

    /// The per-label half of `scores`: one O(q^2) w_aug construction
    /// plus the O(q)-per-point LOO sweep (see the struct docs for the
    /// scalar-cache algebra). Shared by `scores` and `scores_batch`, so
    /// their outputs are bit-identical by construction.
    fn scores_from_prepared(&self, st: &PreparedTest, y: Label) -> Scores {
        let phi = self.phi.as_ref().expect("fit first");
        let model = self.model.as_ref().unwrap();
        let n = phi.rows;
        let y_t = target(y);

        // test score first: f trained on Z only
        let test = -y_t * st.wdot;

        let resid_t = st.wdot - y_t;
        // w_aug = w + u * resid_t/denom_t
        let coef_t = resid_t / st.denom_t;
        let w_aug: Vec<f64> = model
            .w
            .iter()
            .zip(&st.u)
            .map(|(w, ui)| w + ui * coef_t)
            .collect();

        // LOO sweep, O(q) per point:
        //   a_aug   = phi_i^T C_aug phi_i = pcp_i + b^2/denom_t,  b = u.phi_i
        //   denom_i = -ptp_i + rho + a_aug          (decrement denominator)
        //   f(x_i)  = phi_i^T w_aug - (a_aug - ptp_i) (phi_i^T w_aug - y_i)/denom_i
        let mut train = Vec::with_capacity(n);
        for i in 0..n {
            let phi_i = phi.row(i);
            let b = st.bs[i];
            let d = dot(phi_i, &w_aug);
            let a_aug = self.pcp[i] + b * b / st.denom_t;
            let denom_i = -self.ptp[i] + self.rho + a_aug;
            let resid = d - self.ys[i];
            let fx = d - (a_aug - self.ptp[i]) * resid / denom_i;
            train.push(-self.ys[i] * fx);
        }
        Scores { train, test }
    }
}

/// Label-independent scoring state for one test object (LS-SVM).
struct PreparedTest {
    /// u = (C - I) phi_x
    u: Vec<f64>,
    /// incremental-add denominator for the test example
    denom_t: f64,
    /// phi_x . w (both f(x) on Z and the residual base)
    wdot: f64,
    /// b_i = u . phi_i per training point
    bs: Vec<f64>,
}

impl CpMeasure for LsSvmOptimized {
    fn name(&self) -> String {
        "lssvm-optimized".into()
    }

    /// One-off closed-form training: O(n q^2 + q^3) (Table 1 "Train").
    fn fit(&mut self, ds: &Dataset) {
        assert_eq!(ds.n_labels, 2, "LS-SVM CP is binary (use one-vs-rest)");
        let built = self.map.build(ds.p);
        let phi = featurize(&built, ds);
        self.ys = ds.y.iter().map(|&l| target(l)).collect();
        self.model = Some(LsSvmModel::train(&phi, &self.ys, self.rho));
        self.phi = Some(phi);
        self.built = Some(built);
        self.n_labels = ds.n_labels;
        self.refresh_caches();
    }

    /// Prediction: one O(q^2) incremental add of (x, y), then an O(q)
    /// virtual decrement per training point (see the struct docs for
    /// the scalar-cache algebra).
    fn scores(&self, x: &[f64], y: Label) -> Scores {
        self.scores_from_prepared(&self.prepare_test(x), y)
    }

    /// Batched LS-SVM scoring: all label-independent state for the
    /// whole batch — the rank-1 update vectors `U` and the per-point
    /// projection matrix `B` — comes from [`Self::prepare_tests`]'s
    /// three matrix launches, reused across every candidate label; only
    /// the O(n q) virtual-decrement sweep runs per label. Bit-identical
    /// to per-pair [`CpMeasure::scores`] (bit-equal prepared states +
    /// shared [`Self::scores_from_prepared`]).
    fn scores_batch(&self, xs: &[&[f64]], labels: &[Label]) -> Vec<Scores> {
        if xs.is_empty() || labels.is_empty() {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(xs.len() * labels.len());
        for st in self.prepare_tests(xs) {
            for &y in labels {
                out.push(self.scores_from_prepared(&st, y));
            }
        }
        out
    }

    fn n(&self) -> usize {
        self.phi.as_ref().map_or(0, |m| m.rows)
    }

    fn n_labels(&self) -> usize {
        self.n_labels
    }

    /// Online increment: O(q^2) model update + row append.
    fn learn(&mut self, x: &[f64], y: Label) -> bool {
        let (Some(model), Some(phi), Some(built)) =
            (self.model.as_mut(), self.phi.as_mut(), self.built.as_ref())
        else {
            return false;
        };
        let mut phix = Vec::with_capacity(phi.cols);
        built.apply(x, &mut phix);
        let y_t = target(y);
        // maintain pcp under the rank-1 C update: C += u u^T/denom
        // => pcp_i += (u.phi_i)^2/denom   (O(n q))
        let mut u = model.c.matvec(&phix);
        for (ui, &pi) in u.iter_mut().zip(&phix) {
            *ui -= pi;
        }
        let ptp_t = dot(&phix, &phix);
        let denom = ptp_t + self.rho - (dot(&phix, &u) + ptp_t);
        for i in 0..phi.rows {
            let b = dot(&u, phi.row(i));
            self.pcp[i] += b * b / denom;
        }
        model.learn(&phix, y_t);
        // caches for the new row (O(q^2))
        let cphi = model.c.matvec(&phix);
        self.ptp.push(ptp_t);
        self.pcp.push(dot(&phix, &cphi));
        phi.data.extend_from_slice(&phix);
        phi.rows += 1;
        self.ys.push(y_t);
        true
    }

    /// Online decrement: O(q^2) model update + O(n q) cache maintenance.
    fn unlearn(&mut self, idx: usize) -> bool {
        let (Some(model), Some(phi)) = (self.model.as_mut(), self.phi.as_mut())
        else {
            return false;
        };
        if idx >= phi.rows {
            return false;
        }
        let row = phi.row(idx).to_vec();
        // C -= u u^T/denom  => pcp_i -= (u.phi_i)^2/denom
        let mut u = model.c.matvec(&row);
        for (ui, &pi) in u.iter_mut().zip(&row) {
            *ui -= pi;
        }
        let ptp_r = dot(&row, &row);
        let denom = -ptp_r + self.rho + (dot(&row, &u) + ptp_r);
        for i in 0..phi.rows {
            let b = dot(&u, phi.row(i));
            self.pcp[i] -= b * b / denom;
        }
        model.unlearn(&row, self.ys[idx]);
        let q = phi.cols;
        phi.data.drain(idx * q..(idx + 1) * q);
        phi.rows -= 1;
        self.ys.remove(idx);
        self.ptp.remove(idx);
        self.pcp.remove(idx);
        true
    }
}

// ---------------------------------------------------------------------
// ICP
// ---------------------------------------------------------------------

/// Inductive LS-SVM measure.
pub struct IcpLsSvm {
    pub rho: f64,
    pub map: FeatureMap,
    built: Option<BuiltMap>,
    model: Option<LsSvmModel>,
}

impl IcpLsSvm {
    pub fn new(rho: f64, map: FeatureMap) -> Self {
        IcpLsSvm {
            rho,
            map,
            built: None,
            model: None,
        }
    }
}

impl IcpMeasure for IcpLsSvm {
    fn name(&self) -> String {
        "icp-lssvm".into()
    }

    fn fit(&mut self, proper: &Dataset) {
        let built = self.map.build(proper.p);
        let phi = featurize(&built, proper);
        let ys: Vec<f64> = proper.y.iter().map(|&l| target(l)).collect();
        self.model = Some(LsSvmModel::train(&phi, &ys, self.rho));
        self.built = Some(built);
    }

    fn score(&self, x: &[f64], y: Label) -> f64 {
        let model = self.model.as_ref().expect("fit first");
        let built = self.built.as_ref().unwrap();
        let mut phix = Vec::new();
        built.apply(x, &mut phix);
        -target(y) * model.predict_phi(&phix)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_classification, ClassificationSpec};

    fn small_ds(n: usize, seed: u64) -> Dataset {
        make_classification(
            &ClassificationSpec {
                n_samples: n,
                n_features: 5,
                n_informative: 3,
                n_redundant: 1,
                flip_y: 0.0,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn closed_form_matches_normal_equations() {
        // tiny exact case: 1D, phi = x
        let phi = Mat::from_rows(&[&[1.0], &[2.0], &[3.0]]);
        let ys = [1.0, 2.0, 3.0];
        let m = LsSvmModel::train(&phi, &ys, 1.0);
        // w = (sum x y) / (sum x^2 + rho) = 14 / 15
        assert!((m.w[0] - 14.0 / 15.0).abs() < 1e-12);
    }

    #[test]
    fn incremental_add_matches_retrain() {
        let ds = small_ds(30, 1);
        let built = FeatureMap::Linear.build(ds.p);
        let phi = featurize(&built, &ds);
        let ys: Vec<f64> = ds.y.iter().map(|&l| target(l)).collect();
        // train on first 29, add the 30th
        let head = Mat {
            data: phi.data[..29 * phi.cols].to_vec(),
            rows: 29,
            cols: phi.cols,
        };
        let mut m = LsSvmModel::train(&head, &ys[..29], 1.0);
        m.learn(phi.row(29), ys[29]);
        let full = LsSvmModel::train(&phi, &ys, 1.0);
        for (a, b) in m.w.iter().zip(&full.w) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
        for (a, b) in m.c.data.iter().zip(&full.c.data) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn decremental_remove_matches_retrain() {
        let ds = small_ds(30, 2);
        let built = FeatureMap::Linear.build(ds.p);
        let phi = featurize(&built, &ds);
        let ys: Vec<f64> = ds.y.iter().map(|&l| target(l)).collect();
        let mut m = LsSvmModel::train(&phi, &ys, 1.0);
        m.unlearn(phi.row(7), ys[7]);
        // retrain without row 7
        let mut rest = Mat::zeros(29, phi.cols);
        let mut ys_rest = Vec::new();
        let mut r = 0;
        for i in 0..30 {
            if i == 7 {
                continue;
            }
            rest.row_mut(r).copy_from_slice(phi.row(i));
            ys_rest.push(ys[i]);
            r += 1;
        }
        let want = LsSvmModel::train(&rest, &ys_rest, 1.0);
        for (a, b) in m.w.iter().zip(&want.w) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn w_without_matches_unlearn() {
        let ds = small_ds(25, 3);
        let built = FeatureMap::Linear.build(ds.p);
        let phi = featurize(&built, &ds);
        let ys: Vec<f64> = ds.y.iter().map(|&l| target(l)).collect();
        let m = LsSvmModel::train(&phi, &ys, 1.0);
        let mut w_virtual = Vec::new();
        m.w_without(phi.row(3), ys[3], &mut w_virtual);
        let mut m2 = m.clone();
        m2.unlearn(phi.row(3), ys[3]);
        for (a, b) in w_virtual.iter().zip(&m2.w) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn optimized_matches_standard_linear() {
        let ds = small_ds(25, 4);
        let mut s = LsSvmStandard::new(1.0, FeatureMap::Linear);
        let mut o = LsSvmOptimized::new(1.0, FeatureMap::Linear);
        s.fit(&ds);
        o.fit(&ds);
        let probe = small_ds(6, 5);
        for i in 0..probe.n() {
            for y in 0..2 {
                let a = s.scores(probe.row(i), y);
                let b = o.scores(probe.row(i), y);
                for (u, v) in a.train.iter().zip(&b.train) {
                    assert!((u - v).abs() < 1e-7, "{u} vs {v}");
                }
                assert!((a.test - b.test).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn optimized_matches_standard_rff() {
        let ds = small_ds(20, 6);
        let map = FeatureMap::Rff {
            q: 16,
            gamma: 0.5,
            seed: 99,
        };
        let mut s = LsSvmStandard::new(1.0, map.clone());
        let mut o = LsSvmOptimized::new(1.0, map);
        s.fit(&ds);
        o.fit(&ds);
        let probe = small_ds(4, 7);
        for i in 0..probe.n() {
            for y in 0..2 {
                let a = s.scores(probe.row(i), y);
                let b = o.scores(probe.row(i), y);
                for (u, v) in a.train.iter().zip(&b.train) {
                    assert!((u - v).abs() < 1e-7, "{u} vs {v}");
                }
            }
        }
    }

    #[test]
    fn scores_batch_bit_identical_to_single() {
        let ds = small_ds(24, 9);
        let probe = small_ds(5, 10);
        let xs: Vec<&[f64]> = (0..probe.n()).map(|i| probe.row(i)).collect();
        for map in [
            FeatureMap::Linear,
            FeatureMap::Rff {
                q: 12,
                gamma: 0.5,
                seed: 3,
            },
        ] {
            let mut o = LsSvmOptimized::new(1.0, map.clone());
            let mut s = LsSvmStandard::new(1.0, map);
            o.fit(&ds);
            s.fit(&ds);
            for m in [&o as &dyn CpMeasure, &s as &dyn CpMeasure] {
                let batch = m.scores_batch(&xs, &[0, 1]);
                assert_eq!(batch.len(), xs.len() * 2);
                for (xi, x) in xs.iter().enumerate() {
                    for y in 0..2usize {
                        let single = m.scores(x, y);
                        let got = &batch[xi * 2 + y];
                        assert_eq!(got.test.to_bits(), single.test.to_bits());
                        for (a, b) in got.train.iter().zip(&single.train) {
                            assert_eq!(a.to_bits(), b.to_bits());
                        }
                    }
                }
                assert!(m.scores_batch(&[], &[0, 1]).is_empty());
            }
        }
    }

    #[test]
    fn online_learn_unlearn_roundtrip() {
        let ds = small_ds(20, 8);
        let mut m = LsSvmOptimized::new(1.0, FeatureMap::Linear);
        m.fit(&ds);
        let w0 = m.model.as_ref().unwrap().w.clone();
        let x_new = vec![0.3; 5];
        assert!(m.learn(&x_new, 1));
        assert!(m.unlearn(20));
        let w1 = &m.model.as_ref().unwrap().w;
        for (a, b) in w0.iter().zip(w1) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn rff_approximates_gaussian_kernel() {
        // <phi(x), phi(y)> ~= exp(-gamma ||x-y||^2)
        let map = FeatureMap::Rff {
            q: 4096,
            gamma: 0.5,
            seed: 1,
        }
        .build(3);
        let x = [0.1, -0.2, 0.3];
        let y = [0.4, 0.0, -0.1];
        let (mut px, mut py) = (Vec::new(), Vec::new());
        map.apply(&x, &mut px);
        map.apply(&y, &mut py);
        let got = dot(&px, &py);
        let d2: f64 = x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
        let want = (-0.5 * d2).exp();
        assert!((got - want).abs() < 0.05, "{got} vs {want}");
    }
}
