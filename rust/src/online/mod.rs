//! Online exchangeability / IID testing (Vovk et al. 2003; paper §9,
//! App. C.5).
//!
//! At step n+1 the tester computes a *smoothed* conformal p-value for
//! the new observation against the previous n, then incrementally
//! learns it. With the standard k-NN measure each p-value costs O(n^2)
//! (O(n^3) for the whole stream); with the optimized
//! incremental&decremental measure each costs O(n) (O(n^2) total) —
//! exactly App. C.5's accounting, reproduced by `experiment iid`.
//!
//! The p-values feed *exchangeability martingales*: betting processes
//! whose growth refutes exchangeability. We implement the power
//! martingale family and its simple-mixture integral (log-space over an
//! epsilon grid).

use crate::cp::measure::CpMeasure;
use crate::cp::pvalue::smoothed_p_value;
use crate::data::{Dataset, Rng};

/// Power martingale M_n(eps) = prod_i eps p_i^(eps-1), tracked in log
/// space on a grid of eps values; the *simple mixture* martingale is
/// the average over the grid (a numeric integral over eps in [0,1]).
#[derive(Clone, Debug)]
pub struct Martingale {
    /// eps grid (open interval (0,1))
    eps: Vec<f64>,
    /// log M(eps) per grid point
    log_m: Vec<f64>,
    steps: usize,
}

impl Default for Martingale {
    fn default() -> Self {
        Self::new(100)
    }
}

impl Martingale {
    pub fn new(grid: usize) -> Self {
        assert!(grid >= 2);
        let eps: Vec<f64> = (1..=grid)
            .map(|i| i as f64 / (grid + 1) as f64)
            .collect();
        let log_m = vec![0.0; eps.len()];
        Martingale {
            eps,
            log_m,
            steps: 0,
        }
    }

    /// Feed one smoothed p-value.
    pub fn update(&mut self, p: f64) {
        let p = p.clamp(1e-12, 1.0);
        for (lm, &e) in self.log_m.iter_mut().zip(&self.eps) {
            *lm += e.ln() + (e - 1.0) * p.ln();
        }
        self.steps += 1;
    }

    /// log of the simple-mixture martingale value.
    pub fn log_mixture(&self) -> f64 {
        // log mean exp(log_m)
        let max = self
            .log_m
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        if !max.is_finite() {
            return max;
        }
        let sum: f64 = self.log_m.iter().map(|&l| (l - max).exp()).sum();
        max + (sum / self.log_m.len() as f64).ln()
    }

    /// log of the best single power martingale (diagnostic).
    pub fn log_max_power(&self) -> f64 {
        self.log_m
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn steps(&self) -> usize {
        self.steps
    }
}

/// Online exchangeability tester over unlabelled observations, generic
/// in the (single-label) nonconformity measure.
pub struct ExchangeabilityTest<M: CpMeasure> {
    measure: M,
    martingale: Martingale,
    rng: Rng,
    p: usize,
    seen: usize,
    /// p-value history (for diagnostics / benches)
    pub p_values: Vec<f64>,
}

impl<M: CpMeasure> ExchangeabilityTest<M> {
    /// `measure` must be fitted lazily: we bootstrap it with the first
    /// observation (a CP p-value needs at least one reference point).
    pub fn new(measure: M, dim: usize, seed: u64) -> Self {
        ExchangeabilityTest {
            measure,
            martingale: Martingale::default(),
            rng: Rng::seed_from(seed),
            p: dim,
            seen: 0,
            p_values: Vec::new(),
        }
    }

    /// Process one observation: returns its smoothed p-value (None for
    /// the bootstrap observation) and updates the martingale.
    ///
    /// Exactly [`ExchangeabilityTest::observe_batch`] with a singleton
    /// batch — one code path, no drift between the two.
    pub fn observe(&mut self, x: &[f64]) -> Option<f64> {
        self.observe_batch(&[x]).pop().unwrap()
    }

    /// Mini-batch variant of [`observe`]: scores every observation in
    /// `xs` against the state at the start of the batch with one
    /// [`CpMeasure::scores_batch`] call, then learns them all (in
    /// order). Returns one entry per observation, `None` for the
    /// bootstrap observation. Exception: when the tester is fresh
    /// (`seen == 0`), the first observation bootstraps the measure and
    /// the REST of the batch is scored against that post-bootstrap
    /// state (a CP p-value needs at least one reference point).
    ///
    /// With `xs.len() == 1` this is exactly [`observe`] (same scores,
    /// same RNG draws, same martingale updates). For larger batches the
    /// p-values differ from the sequential tester in that observations
    /// within one batch are not conditioned on each other — the
    /// trade-off that lets a high-throughput stream amortize one
    /// distance row per observation across the batch.
    ///
    /// Like [`observe`], this requires a measure with real incremental
    /// `learn` support (the optimized variants): for measures whose
    /// `learn` returns false, the fallback refit keeps only the latest
    /// observation (the same degenerate completeness branch as
    /// [`observe`]) and the martingale output is meaningless.
    ///
    /// [`observe`]: ExchangeabilityTest::observe
    pub fn observe_batch(&mut self, xs: &[&[f64]]) -> Vec<Option<f64>> {
        let mut out = Vec::with_capacity(xs.len());
        let mut rest = xs;
        if self.seen == 0 {
            let Some((first, tail)) = xs.split_first() else {
                return out;
            };
            assert_eq!(first.len(), self.p);
            let ds = Dataset::new(first.to_vec(), vec![0], self.p, 1);
            self.measure.fit(&ds);
            self.seen = 1;
            out.push(None);
            rest = tail;
        }
        if rest.is_empty() {
            return out;
        }
        for x in rest {
            assert_eq!(x.len(), self.p);
        }
        let scores = self.measure.scores_batch(rest, &[0]);
        for (x, s) in rest.iter().zip(scores) {
            let tau = self.rng.f64();
            let p = smoothed_p_value(&s, tau);
            self.martingale.update(p);
            self.p_values.push(p);
            if !self.measure.learn(x, 0) {
                // non-incremental measures: degenerate refit keeping
                // only the latest observation (no access to the
                // measure's data; callers should use optimized
                // measures — see the doc caveat above)
                let mut all = Dataset::new(Vec::new(), Vec::new(), self.p, 1);
                all.push(x, 0);
                self.measure.fit(&all);
            }
            self.seen += 1;
            out.push(Some(p));
        }
        out
    }

    /// Current log simple-mixture martingale (evidence against
    /// exchangeability; ln 100 ~ 4.6 is the usual alarm bar).
    pub fn log_martingale(&self) -> f64 {
        self.martingale.log_mixture()
    }

    /// log of the best single power martingale in the mixture
    /// (diagnostic, surfaced by the coordinator's `stats` op).
    pub fn log_max_power(&self) -> f64 {
        self.martingale.log_max_power()
    }

    pub fn measure(&self) -> &M {
        &self.measure
    }

    /// Observations processed so far (including the bootstrap one).
    pub fn seen(&self) -> usize {
        self.seen
    }

    /// Expected observation dimension.
    pub fn dim(&self) -> usize {
        self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::measures::knn::KnnOptimized;

    fn stream_iid(n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::seed_from(seed);
        (0..n)
            .map(|_| (0..3).map(|_| rng.normal()).collect())
            .collect()
    }

    #[test]
    fn martingale_stays_low_under_iid() {
        let mut t =
            ExchangeabilityTest::new(KnnOptimized::new(3, true), 3, 1);
        for x in stream_iid(150, 2) {
            t.observe(&x);
        }
        let lm = t.log_martingale();
        // Ville: P(sup M >= 100) <= 1/100 — log M should stay well below
        assert!(lm < 100f64.ln(), "log mixture {lm}");
    }

    #[test]
    fn martingale_grows_under_change_point() {
        let mut t =
            ExchangeabilityTest::new(KnnOptimized::new(3, true), 3, 3);
        let mut stream = stream_iid(100, 4);
        // drastic distribution shift: shifted cluster
        for x in stream_iid(100, 5) {
            stream.push(x.iter().map(|v| v + 8.0).collect());
        }
        let mut after_shift = f64::NEG_INFINITY;
        for (i, x) in stream.iter().enumerate() {
            t.observe(x);
            if i == stream.len() - 1 {
                after_shift = t.log_martingale();
            }
        }
        assert!(
            after_shift > 100f64.ln(),
            "martingale failed to detect shift: {after_shift}"
        );
    }

    #[test]
    fn p_values_roughly_uniform_under_iid() {
        let mut t =
            ExchangeabilityTest::new(KnnOptimized::new(3, true), 3, 6);
        for x in stream_iid(300, 7) {
            t.observe(&x);
        }
        let ps = &t.p_values;
        let mean: f64 = ps.iter().sum::<f64>() / ps.len() as f64;
        assert!((mean - 0.5).abs() < 0.08, "mean p {mean}");
        // KS-lite: empirical CDF at quartiles
        for q in [0.25, 0.5, 0.75] {
            let frac =
                ps.iter().filter(|&&p| p <= q).count() as f64 / ps.len() as f64;
            assert!((frac - q).abs() < 0.12, "F({q}) = {frac}");
        }
    }

    #[test]
    fn observe_batch_of_one_equals_observe() {
        let stream = stream_iid(80, 21);
        let mut seq =
            ExchangeabilityTest::new(KnnOptimized::new(3, true), 3, 9);
        let mut bat =
            ExchangeabilityTest::new(KnnOptimized::new(3, true), 3, 9);
        for x in &stream {
            let a = seq.observe(x);
            let b = bat.observe_batch(&[x.as_slice()]);
            assert_eq!(b.len(), 1);
            assert_eq!(a, b[0]);
        }
        assert_eq!(seq.p_values, bat.p_values);
        assert_eq!(seq.log_martingale(), bat.log_martingale());
    }

    #[test]
    fn observe_batch_scores_against_batch_start_state() {
        let stream = stream_iid(40, 22);
        let mut t =
            ExchangeabilityTest::new(KnnOptimized::new(3, true), 3, 10);
        let (head, tail) = stream.split_at(30);
        for x in head {
            t.observe(x);
        }
        // scores from the frozen pre-batch state (what the batch must use)
        let frozen: Vec<crate::cp::measure::Scores> =
            tail.iter().map(|x| t.measure().scores(x, 0)).collect();
        let rng_probe = t.rng.clone();
        let xs: Vec<&[f64]> = tail.iter().map(|x| x.as_slice()).collect();
        let got = t.observe_batch(&xs);
        // replay the tau draws against the frozen scores
        let mut rng = rng_probe;
        for (s, p) in frozen.iter().zip(&got) {
            let want = smoothed_p_value(s, rng.f64());
            assert_eq!(p.unwrap(), want);
        }
        assert_eq!(got.len(), 10);
        assert!(got.iter().all(|p| p.is_some()));
        assert_eq!(t.p_values.len(), 29 + 10);
        // all observations were learned
        assert_eq!(t.measure().n(), 40);
    }

    #[test]
    fn martingale_mixture_bounded_by_max_power() {
        let mut m = Martingale::new(50);
        for p in [0.5, 0.1, 0.9, 0.3, 0.7] {
            m.update(p);
        }
        assert!(m.log_mixture() <= m.log_max_power());
        assert_eq!(m.steps(), 5);
    }
}
