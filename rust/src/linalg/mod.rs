//! Dense linear-algebra substrate.
//!
//! Everything the LS-SVM measure (§5 / App. B.1) and the ridge CP
//! regressor need: a small row-major matrix type, matmul/matvec,
//! Cholesky factorization + SPD solve/inverse. Written from scratch so
//! the crate is dependency-light and the hot loops are auditable; the
//! PJRT runtime is the alternative backend for the distance kernels.

pub mod distance;
pub mod engine;
pub mod select;

pub use distance::{
    dist_matrix_sq, dist_matrix_sq_into, dist_matrix_sq_into_workers, dist_row_sq,
    dist_row_sq_into, pairwise_sq, Backend,
};
pub use engine::{
    native, native_with_workers, DistEngine, Engine, NativeEngine, ThreadedNativeEngine,
};
pub use select::{k_smallest, k_smallest_by};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub data: Vec<f64>,
    pub rows: usize,
    pub cols: usize,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            data: vec![0.0; rows * cols],
            rows,
            cols,
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { data, rows: r, cols: c }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * v` for a column vector `v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            out[i] = dot(self.row(i), v);
        }
        out
    }

    /// `self^T * v`.
    pub fn tmatvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            tmatvec_accum_row(&mut out, v[i], self.row(i));
        }
        out
    }

    /// `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj loop order: streams `other` rows, cache-friendly row-major.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self^T * self` (Gram matrix), exploiting symmetry.
    pub fn gram(&self) -> Mat {
        let q = self.cols;
        let mut g = Mat::zeros(q, q);
        for r in 0..self.rows {
            gram_accum_row(&mut g, self.row(r));
        }
        g.mirror_upper_to_lower();
        g
    }

    /// Copy the (strict) upper triangle onto the lower one — the
    /// finalization step of [`Mat::gram`], exposed so incremental
    /// callers that accumulate the upper triangle row by row (via
    /// [`gram_accum_row`]) can finish exactly like the one-shot path.
    pub fn mirror_upper_to_lower(&mut self) {
        let q = self.rows.min(self.cols);
        for i in 0..q {
            for j in 0..i {
                self[(i, j)] = self[(j, i)];
            }
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Add `alpha` to the diagonal in place.
    pub fn add_diag(&mut self, alpha: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += alpha;
        }
    }

    /// Rank-1 update `self += alpha * u v^T`.
    pub fn rank1_update(&mut self, alpha: f64, u: &[f64], v: &[f64]) {
        assert_eq!(u.len(), self.rows);
        assert_eq!(v.len(), self.cols);
        for i in 0..self.rows {
            let s = alpha * u[i];
            if s == 0.0 {
                continue;
            }
            for (o, &b) in self.row_mut(i).iter_mut().zip(v) {
                *o += s * b;
            }
        }
    }
}

/// One row's rank-1 contribution `row row^T` to the upper triangle of a
/// Gram accumulator — the exact inner body of [`Mat::gram`], factored
/// out so incremental/decremental callers (the ridge sufficient-statistic
/// journal) replay the one-shot fit's add sequence term for term. Only
/// the upper triangle (`j >= i`) is touched; finish with
/// [`Mat::mirror_upper_to_lower`] after the last row.
pub fn gram_accum_row(g: &mut Mat, row: &[f64]) {
    let q = row.len();
    debug_assert_eq!(g.rows, q);
    debug_assert_eq!(g.cols, q);
    for i in 0..q {
        let ri = row[i];
        if ri == 0.0 {
            continue;
        }
        for j in i..q {
            g[(i, j)] += ri * row[j];
        }
    }
}

/// One row's contribution `vi * row` to a `self^T v` accumulator — the
/// exact inner body of [`Mat::tmatvec`], factored out for the same
/// sequential-replay reason as [`gram_accum_row`].
pub fn tmatvec_accum_row(out: &mut [f64], vi: f64, row: &[f64]) {
    for (o, &a) in out.iter_mut().zip(row) {
        *o += vi * a;
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than the naive fold
    // on the LS-SVM hot path, and gives the compiler clean auto-vec.
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// All row-by-row dot products between `a` (`m x q`) and `b` (`n x q`):
/// row-major `m x n` output with `out[i, j] = dot(a.row(i), b.row(j))`.
///
/// The batch analogue of calling [`dot`] in a loop (LS-SVM projection
/// assembly): each entry replays [`dot`]'s exact operation sequence, so
/// the result is bit-identical to the per-row path, and the `b` rows
/// are walked innermost in blocks so they stay cache-hot across the
/// `a` tile — same scheme as `distance::dist_matrix_sq_into`.
pub fn dot_matrix(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols);
    let mut out = Mat::zeros(a.rows, b.rows);
    let block = (3072 / a.cols.max(1)).max(1);
    let mut j0 = 0;
    while j0 < b.rows {
        let j1 = (j0 + block).min(b.rows);
        for i in 0..a.rows {
            let arow = a.row(i);
            let orow = out.row_mut(i);
            for j in j0..j1 {
                orow[j] = dot(arow, b.row(j));
            }
        }
        j0 = j1;
    }
    out
}

/// Cholesky factorization of an SPD matrix: returns lower-triangular L
/// with `A = L L^T`, or None if not positive definite.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve `A x = b` for SPD `A` via its Cholesky factor `l`.
pub fn chol_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    // forward: L z = b
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * z[k];
        }
        z[i] = s / l[(i, i)];
    }
    // backward: L^T x = z
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Inverse of an SPD matrix via Cholesky (column-by-column solve).
pub fn spd_inverse(a: &Mat) -> Option<Mat> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = chol_solve(&l, &e);
        e[j] = 0.0;
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::seed_from(seed);
        Mat {
            data: (0..r * c).map(|_| rng.normal()).collect(),
            rows: r,
            cols: c,
        }
    }

    #[test]
    fn matmul_identity() {
        let a = rand_mat(5, 5, 1);
        let i = Mat::eye(5);
        let prod = a.matmul(&i);
        for (x, y) in prod.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1., 2.], &[3., 4.]]);
        let b = Mat::from_rows(&[&[5., 6.], &[7., 8.]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matvec_tmatvec_consistent() {
        let a = rand_mat(4, 7, 2);
        let v = vec![1.0; 7];
        let w = vec![1.0; 4];
        let av = a.matvec(&v);
        let atw = a.tmatvec(&w);
        // sum over all entries both ways
        let s1: f64 = av.iter().sum();
        let s2: f64 = atw.iter().sum();
        assert!((s1 - s2).abs() < 1e-10);
    }

    #[test]
    fn gram_matches_explicit() {
        let a = rand_mat(6, 4, 3);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for (x, y) in g.data.iter().zip(&g2.data) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn row_accumulators_replay_one_shot_bitwise() {
        // the property the ridge journal rests on: accumulating row by
        // row — including resuming from a mid-stream prefix checkpoint —
        // reproduces the one-shot gram()/tmatvec() bit for bit.
        let a = rand_mat(9, 5, 11);
        let v: Vec<f64> = (0..9).map(|i| (i as f64) - 4.0).collect();
        let (g1, t1) = (a.gram(), a.tmatvec(&v));
        let mut g2 = Mat::zeros(5, 5);
        let mut t2 = vec![0.0; 5];
        let mut ckpt = None;
        for r in 0..a.rows {
            if r == 4 {
                ckpt = Some((g2.clone(), t2.clone()));
            }
            gram_accum_row(&mut g2, a.row(r));
            tmatvec_accum_row(&mut t2, v[r], a.row(r));
        }
        // resume from the checkpoint and replay the suffix
        let (mut g3, mut t3) = ckpt.unwrap();
        for r in 4..a.rows {
            gram_accum_row(&mut g3, a.row(r));
            tmatvec_accum_row(&mut t3, v[r], a.row(r));
        }
        g2.mirror_upper_to_lower();
        g3.mirror_upper_to_lower();
        for (x, y) in g1.data.iter().zip(&g2.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in g1.data.iter().zip(&g3.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in t1.iter().zip(&t2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in t1.iter().zip(&t3) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn cholesky_solve_roundtrip() {
        // SPD matrix: G = A^T A + I
        let a = rand_mat(8, 8, 4);
        let mut g = a.gram();
        g.add_diag(1.0 + 8.0);
        let l = cholesky(&g).expect("SPD");
        let b: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let x = chol_solve(&l, &b);
        let back = g.matvec(&x);
        for (u, v) in back.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }

    #[test]
    fn spd_inverse_roundtrip() {
        let a = rand_mat(6, 6, 5);
        let mut g = a.gram();
        g.add_diag(2.0);
        let inv = spd_inverse(&g).unwrap();
        let prod = g.matmul(&inv);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = Mat::from_rows(&[&[1., 2.], &[2., 1.]]); // eigenvalues 3, -1
        assert!(cholesky(&m).is_none());
    }

    #[test]
    fn rank1_update_matches_dense() {
        let mut m = rand_mat(5, 5, 6);
        let m0 = m.clone();
        let u: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let v: Vec<f64> = (0..5).map(|i| (i as f64).sin()).collect();
        m.rank1_update(0.5, &u, &v);
        for i in 0..5 {
            for j in 0..5 {
                let want = m0[(i, j)] + 0.5 * u[i] * v[j];
                assert!((m[(i, j)] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn dot_matrix_bitwise_equals_per_row_dot() {
        let a = rand_mat(9, 5, 11);
        let b = rand_mat(6, 5, 12);
        let m = dot_matrix(&a, &b);
        for i in 0..9 {
            for j in 0..6 {
                assert_eq!(m[(i, j)].to_bits(), dot(a.row(i), b.row(j)).to_bits());
            }
        }
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let mut rng = Rng::seed_from(7);
        for len in [0, 1, 3, 4, 7, 30, 31, 32, 33, 101] {
            let a: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-9 * (1.0 + naive.abs()));
        }
    }
}
