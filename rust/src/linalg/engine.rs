//! Pluggable distance engine: the seam between the CP algorithms (L3)
//! and the compute backend (native Rust loops vs AOT-compiled
//! Pallas/JAX kernels executed over PJRT).
//!
//! The optimized measures are generic over this trait, so the exactness
//! tests can run the *same* algorithm on both backends and assert the
//! p-values agree.

use std::sync::Arc;

use crate::linalg::distance;

/// Engine for the distance hot-spots.
pub trait DistEngine: Send + Sync {
    /// Squared distances from `x` to every row of `rows` (n x p).
    fn dist_row_sq(&self, x: &[f64], rows: &[f64], p: usize, out: &mut [f64]);

    /// Full pairwise squared-distance matrix over rows of `a` (n x p),
    /// row-major n x n output.
    fn pairwise_sq(&self, a: &[f64], p: usize) -> Vec<f64> {
        // Default: n applications of the row kernel.
        let n = a.len() / p;
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            let (head, tail) = out.split_at_mut(i * n);
            let _ = head;
            let row = &mut tail[..n];
            self.dist_row_sq(&a[i * p..(i + 1) * p], a, p, row);
        }
        out
    }

    /// Gaussian kernel row exp(-d^2 / (2 h^2)) from `x` to every row.
    fn kde_row(&self, x: &[f64], rows: &[f64], p: usize, h2: f64, out: &mut [f64]) {
        self.dist_row_sq(x, rows, p, out);
        for v in out.iter_mut() {
            *v = (-*v / (2.0 * h2)).exp();
        }
    }

    /// Full `m x n` squared-distance matrix between the rows of `xs`
    /// (`m x p`) and the rows of `rows` (`n x p`), row-major into `out`
    /// (len `m * n`) — one launch per batch instead of one per row.
    ///
    /// Default: `m` applications of the row kernel. Overrides must keep
    /// the determinism contract of `distance::dist_matrix_sq_into`:
    /// bit-identical to the stacked rows.
    fn dist_matrix_sq(&self, xs: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
        if p == 0 {
            return;
        }
        let n = rows.len() / p;
        if n == 0 {
            return;
        }
        for (x, o) in xs.chunks_exact(p).zip(out.chunks_exact_mut(n)) {
            self.dist_row_sq(x, rows, p, o);
        }
    }

    /// Gaussian kernel matrix exp(-d^2 / (2 h^2)): [`Self::dist_matrix_sq`]
    /// followed by the same per-element map as [`Self::kde_row`], so each
    /// output row is bit-identical to the row kernel.
    fn kde_matrix(&self, xs: &[f64], rows: &[f64], p: usize, h2: f64, out: &mut [f64]) {
        self.dist_matrix_sq(xs, rows, p, out);
        for v in out.iter_mut() {
            *v = (-*v / (2.0 * h2)).exp();
        }
    }

    fn name(&self) -> &'static str;
}

/// `DistKernel` trace span for one batch matrix launch, or `None` when
/// tracing is off (one relaxed load). args = [m, n, p, engine_id] per
/// the [`crate::obs::Stage::DistKernel`] contract. Shared by the native
/// engines here and the PJRT engines in `runtime::{pjrt,stub}`.
pub(crate) fn kernel_span(
    engine: u64,
    xs: &[f64],
    rows: &[f64],
    p: usize,
) -> Option<crate::obs::trace::SpanGuard> {
    if p == 0 {
        return None;
    }
    crate::obs::trace::span_args(
        crate::obs::Stage::DistKernel,
        [
            (xs.len() / p) as u64,
            (rows.len() / p) as u64,
            p as u64,
            engine,
        ],
    )
}

/// Hand-written Rust loops (default backend).
#[derive(Default, Clone, Copy, Debug)]
pub struct NativeEngine;

impl DistEngine for NativeEngine {
    fn dist_row_sq(&self, x: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
        distance::dist_row_sq_into(x, rows, p, out);
    }

    fn pairwise_sq(&self, a: &[f64], p: usize) -> Vec<f64> {
        let _span =
            kernel_span(crate::obs::trace::engine_id::NATIVE, a, a, p);
        distance::pairwise_sq(a, p)
    }

    fn dist_matrix_sq(&self, xs: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
        let _span =
            kernel_span(crate::obs::trace::engine_id::NATIVE, xs, rows, p);
        distance::dist_matrix_sq_into(xs, rows, p, out);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Native loops with the matrix kernel's test-row tiles spread over a
/// scoped-thread worker pool. Output bytes are identical to
/// [`NativeEngine`] for every worker count (see
/// `distance::dist_matrix_sq_into_workers`); only throughput changes.
#[derive(Clone, Copy, Debug)]
pub struct ThreadedNativeEngine {
    pub workers: usize,
}

impl DistEngine for ThreadedNativeEngine {
    fn dist_row_sq(&self, x: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
        distance::dist_row_sq_into(x, rows, p, out);
    }

    fn pairwise_sq(&self, a: &[f64], p: usize) -> Vec<f64> {
        let _span =
            kernel_span(crate::obs::trace::engine_id::THREADED, a, a, p);
        distance::pairwise_sq(a, p)
    }

    fn dist_matrix_sq(&self, xs: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
        let _span =
            kernel_span(crate::obs::trace::engine_id::THREADED, xs, rows, p);
        distance::dist_matrix_sq_into_workers(xs, rows, p, self.workers, out);
    }

    fn name(&self) -> &'static str {
        "native-threaded"
    }
}

/// Shared engine handle.
pub type Engine = Arc<dyn DistEngine>;

/// The default (native) engine.
pub fn native() -> Engine {
    Arc::new(NativeEngine)
}

/// Native engine with `workers` threads for the batch matrix kernel
/// (`workers <= 1` returns the plain serial engine).
pub fn native_with_workers(workers: usize) -> Engine {
    if workers <= 1 {
        Arc::new(NativeEngine)
    } else {
        Arc::new(ThreadedNativeEngine { workers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_pairwise_matches_specialized() {
        let a = vec![0., 0., 1., 0., 0., 2., 3., 3.]; // 4 x 2
        struct RowOnly;
        impl DistEngine for RowOnly {
            fn dist_row_sq(&self, x: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
                distance::dist_row_sq_into(x, rows, p, out);
            }
            fn name(&self) -> &'static str {
                "rowonly"
            }
        }
        let via_default = RowOnly.pairwise_sq(&a, 2);
        let via_native = NativeEngine.pairwise_sq(&a, 2);
        assert_eq!(via_default, via_native);
    }

    #[test]
    fn default_matrix_matches_native_bitwise() {
        struct RowOnly;
        impl DistEngine for RowOnly {
            fn dist_row_sq(&self, x: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
                distance::dist_row_sq_into(x, rows, p, out);
            }
            fn name(&self) -> &'static str {
                "rowonly"
            }
        }
        let xs: Vec<f64> = (0..15).map(|i| i as f64 * 0.37).collect(); // 5 x 3
        let rows: Vec<f64> = (0..21).map(|i| 2.1 - i as f64 * 0.11).collect(); // 7 x 3
        let mut via_default = vec![0.0; 35];
        let mut via_native = vec![0.0; 35];
        RowOnly.dist_matrix_sq(&xs, &rows, 3, &mut via_default);
        NativeEngine.dist_matrix_sq(&xs, &rows, 3, &mut via_native);
        for (a, b) in via_default.iter().zip(&via_native) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        ThreadedNativeEngine { workers: 2 }.dist_matrix_sq(&xs, &rows, 3, &mut via_default);
        for (a, b) in via_default.iter().zip(&via_native) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // kde_matrix rows == kde_row, bit for bit
        let mut km = vec![0.0; 35];
        NativeEngine.kde_matrix(&xs, &rows, 3, 0.7, &mut km);
        let mut kr = vec![0.0; 7];
        for i in 0..5 {
            NativeEngine.kde_row(&xs[i * 3..(i + 1) * 3], &rows, 3, 0.7, &mut kr);
            for j in 0..7 {
                assert_eq!(km[i * 7 + j].to_bits(), kr[j].to_bits());
            }
        }
    }

    #[test]
    fn kde_row_default_matches_formula() {
        let rows = vec![0., 0., 1., 0.];
        let mut out = vec![0.0; 2];
        NativeEngine.kde_row(&[0., 0.], &rows, 2, 0.5, &mut out);
        assert!((out[0] - 1.0).abs() < 1e-12);
        assert!((out[1] - (-1.0f64).exp()).abs() < 1e-12);
    }
}
