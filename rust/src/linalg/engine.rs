//! Pluggable distance engine: the seam between the CP algorithms (L3)
//! and the compute backend (native Rust loops vs AOT-compiled
//! Pallas/JAX kernels executed over PJRT).
//!
//! The optimized measures are generic over this trait, so the exactness
//! tests can run the *same* algorithm on both backends and assert the
//! p-values agree.

use std::sync::Arc;

use crate::linalg::distance;

/// Engine for the distance hot-spots.
pub trait DistEngine: Send + Sync {
    /// Squared distances from `x` to every row of `rows` (n x p).
    fn dist_row_sq(&self, x: &[f64], rows: &[f64], p: usize, out: &mut [f64]);

    /// Full pairwise squared-distance matrix over rows of `a` (n x p),
    /// row-major n x n output.
    fn pairwise_sq(&self, a: &[f64], p: usize) -> Vec<f64> {
        // Default: n applications of the row kernel.
        let n = a.len() / p;
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            let (head, tail) = out.split_at_mut(i * n);
            let _ = head;
            let row = &mut tail[..n];
            self.dist_row_sq(&a[i * p..(i + 1) * p], a, p, row);
        }
        out
    }

    /// Gaussian kernel row exp(-d^2 / (2 h^2)) from `x` to every row.
    fn kde_row(&self, x: &[f64], rows: &[f64], p: usize, h2: f64, out: &mut [f64]) {
        self.dist_row_sq(x, rows, p, out);
        for v in out.iter_mut() {
            *v = (-*v / (2.0 * h2)).exp();
        }
    }

    fn name(&self) -> &'static str;
}

/// Hand-written Rust loops (default backend).
#[derive(Default, Clone, Copy, Debug)]
pub struct NativeEngine;

impl DistEngine for NativeEngine {
    fn dist_row_sq(&self, x: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
        distance::dist_row_sq_into(x, rows, p, out);
    }

    fn pairwise_sq(&self, a: &[f64], p: usize) -> Vec<f64> {
        distance::pairwise_sq(a, p)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Shared engine handle.
pub type Engine = Arc<dyn DistEngine>;

/// The default (native) engine.
pub fn native() -> Engine {
    Arc::new(NativeEngine)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_pairwise_matches_specialized() {
        let a = vec![0., 0., 1., 0., 0., 2., 3., 3.]; // 4 x 2
        struct RowOnly;
        impl DistEngine for RowOnly {
            fn dist_row_sq(&self, x: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
                distance::dist_row_sq_into(x, rows, p, out);
            }
            fn name(&self) -> &'static str {
                "rowonly"
            }
        }
        let via_default = RowOnly.pairwise_sq(&a, 2);
        let via_native = NativeEngine.pairwise_sq(&a, 2);
        assert_eq!(via_default, via_native);
    }

    #[test]
    fn kde_row_default_matches_formula() {
        let rows = vec![0., 0., 1., 0.];
        let mut out = vec![0.0; 2];
        NativeEngine.kde_row(&[0., 0.], &rows, 2, 0.5, &mut out);
        assert!((out[0] - 1.0).abs() < 1e-12);
        assert!((out[1] - (-1.0f64).exp()).abs() < 1e-12);
    }
}
