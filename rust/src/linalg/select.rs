//! k-smallest selection — the `best_k` routine of App. C.1.
//!
//! The paper instantiates `best_k` to introselect (numpy's
//! `argpartition`), O(n) worst case. Rust's `select_nth_unstable` is the
//! same algorithm (median-of-medians fallback quickselect), so the
//! optimized measures here have the exact complexity profile the paper
//! analyzes.

/// Return the `k` smallest values of `xs` in ascending order.
/// If `k >= xs.len()`, returns all of `xs` sorted.
pub fn k_smallest(xs: &[f64], k: usize) -> Vec<f64> {
    let mut v = xs.to_vec();
    let k = k.min(v.len());
    if k == 0 {
        return Vec::new();
    }
    if k < v.len() {
        v.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
        v.truncate(k);
    }
    v.sort_unstable_by(|a, b| a.total_cmp(b));
    v
}

/// k smallest of `items` under `key`, ascending by key. O(n + k log k).
pub fn k_smallest_by<T: Clone>(
    items: &[T],
    k: usize,
    key: impl Fn(&T) -> f64,
) -> Vec<T> {
    let mut v = items.to_vec();
    let k = k.min(v.len());
    if k == 0 {
        return Vec::new();
    }
    if k < v.len() {
        v.select_nth_unstable_by(k - 1, |a, b| key(a).total_cmp(&key(b)));
        v.truncate(k);
    }
    v.sort_unstable_by(|a, b| key(a).total_cmp(&key(b)));
    v
}

/// Bounded max-structure holding the k smallest values seen so far.
///
/// This is the incremental half of the k-NN optimization: each training
/// point keeps its k best same-label (and for full k-NN, different-label)
/// distances; learning a new example is an O(k) `insert`, and the
/// provisional-score update of §3.1 needs only `max()` and `sum()`.
/// k is small (paper: 15), so a sorted array beats a heap.
#[derive(Clone, Debug)]
pub struct KBest {
    k: usize,
    /// ascending
    vals: Vec<f64>,
    sum: f64,
}

impl KBest {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        KBest {
            k,
            vals: Vec::with_capacity(k + 1),
            sum: 0.0,
        }
    }

    /// Build from an unordered candidate set.
    pub fn from_slice(k: usize, xs: &[f64]) -> Self {
        let vals = k_smallest(xs, k);
        let sum = vals.iter().sum();
        KBest { k, vals, sum }
    }

    /// Number of stored distances (may be < k when fewer candidates exist).
    #[inline]
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// True when the structure holds a full complement of k values.
    #[inline]
    pub fn full(&self) -> bool {
        self.vals.len() == self.k
    }

    /// Sum of the stored (<= k) smallest values.
    #[inline]
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Largest stored value (the k-th smallest when full), or +inf when
    /// empty — so `d < kbest.max()` is exactly the "x enters the k-NN
    /// set" test of §3.1 in all fill states.
    #[inline]
    pub fn max(&self) -> f64 {
        self.vals.last().copied().unwrap_or(f64::INFINITY)
    }

    /// Sum if `d` were inserted (without mutating): the §3.1 update rule
    ///   alpha_i = alpha'_i - Delta_i^k + d   if d < Delta_i^k
    /// generalized to the under-full case (new value simply joins).
    #[inline]
    pub fn sum_with(&self, d: f64) -> f64 {
        if !self.full() {
            self.sum + d
        } else if d < self.max() {
            self.sum - self.max() + d
        } else {
            self.sum
        }
    }

    /// Incrementally learn a new distance. O(k).
    pub fn insert(&mut self, d: f64) {
        let pos = self.vals.partition_point(|&v| v <= d);
        if self.vals.len() < self.k {
            self.vals.insert(pos, d);
            self.sum += d;
        } else if pos < self.k {
            self.sum += d - self.vals[self.k - 1];
            self.vals.pop();
            self.vals.insert(pos, d);
        }
    }

    /// Stored values, ascending.
    pub fn values(&self) -> &[f64] {
        &self.vals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_smallest_basic() {
        let xs = [5., 1., 4., 2., 3.];
        assert_eq!(k_smallest(&xs, 3), vec![1., 2., 3.]);
        assert_eq!(k_smallest(&xs, 0), Vec::<f64>::new());
        assert_eq!(k_smallest(&xs, 10), vec![1., 2., 3., 4., 5.]);
    }

    #[test]
    fn k_smallest_with_ties_and_inf() {
        let xs = [2., 2., f64::INFINITY, 1., 1.];
        assert_eq!(k_smallest(&xs, 3), vec![1., 1., 2.]);
    }

    #[test]
    fn k_smallest_by_keys() {
        let items = [(0, 5.0), (1, 1.0), (2, 3.0)];
        let got = k_smallest_by(&items, 2, |t| t.1);
        assert_eq!(got.iter().map(|t| t.0).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn kbest_matches_sort_under_inserts() {
        use crate::data::Rng;
        let mut rng = Rng::seed_from(5);
        for _ in 0..50 {
            let k = 1 + rng.below(6);
            let n = rng.below(20);
            let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
            let mut kb = KBest::new(k);
            for &x in &xs {
                kb.insert(x);
            }
            let want = k_smallest(&xs, k);
            assert_eq!(kb.values(), &want[..], "k={k} xs={xs:?}");
            let sum: f64 = want.iter().sum();
            assert!((kb.sum() - sum).abs() < 1e-9);
        }
    }

    #[test]
    fn kbest_sum_with_semantics() {
        let mut kb = KBest::new(2);
        assert_eq!(kb.max(), f64::INFINITY);
        assert_eq!(kb.sum_with(3.0), 3.0); // under-full: joins
        kb.insert(5.0);
        assert_eq!(kb.sum_with(3.0), 8.0); // still under-full
        kb.insert(4.0);
        assert_eq!(kb.sum(), 9.0);
        assert_eq!(kb.max(), 5.0);
        assert_eq!(kb.sum_with(3.0), 7.0); // evicts the 5
        assert_eq!(kb.sum_with(6.0), 9.0); // no change
    }
}
