//! Distance kernels — the compute hot-spot of every nearest-neighbour
//! family measure (native CPU implementations; `runtime::PjrtEngine`
//! provides the AOT/PJRT-executed alternative for the same entry points).
//!
//! # Batch matrix kernel: tiling scheme
//!
//! [`dist_matrix_sq_into`] computes the full `m x n` squared-distance
//! matrix between `m` test rows and `n` training rows with cache-blocked
//! tiling:
//!
//! - the training rows are walked in blocks of ~`L1_BLOCK_F64` doubles
//!   so each block stays resident in L1 while every test tile visits it;
//! - the test rows are walked in tiles of [`TILE_M`] rows, and the
//!   [`sq_dist_x4`] microkernel accumulates all four test rows against
//!   one training row per pass, so each training-row chunk is loaded
//!   once per four outputs instead of once per output.
//!
//! # Determinism contract
//!
//! Every entry `out[i * n + j]` is produced by the *exact* floating
//! point operation sequence of [`sq_dist`] applied to (test row `i`,
//! training row `j`): same 4-lane accumulators, same lane-sum order,
//! same scalar tail. Tiling only reorders *which entry is computed
//! when*, never the operations inside an entry, so the matrix kernel is
//! bit-identical to `m` stacked [`dist_row_sq_into`] calls — and
//! [`dist_matrix_sq_into_workers`] hands disjoint (test-tile, output
//! tile) pairs to scoped threads, so the output bytes are also
//! independent of the worker count. Locked by `tests/proptests.rs` and
//! the smoke mode of `benches/dist_matrix.rs`.

use std::sync::Mutex;

/// Which engine computes distance rows/matrices.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// Hand-written Rust loops (default; fastest on this 1-core testbed).
    #[default]
    Native,
    /// AOT-compiled Pallas/JAX kernels executed via the PJRT C API.
    Pjrt,
}

/// Test-row tile height of the matrix microkernel.
const TILE_M: usize = 4;

/// Training-row block budget in doubles (~24 KiB, half of a typical
/// 48 KiB L1d so the test tile and output lines fit alongside it).
const L1_BLOCK_F64: usize = 3072;

/// Test rows per parallel job handed to a worker thread.
const PAR_TILE_M: usize = 8;

/// Squared Euclidean distance between two vectors.
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // chunks_exact gives the compiler bounds-check-free, SIMD-friendly
    // bodies (§Perf: measurably better than manual indexing).
    let mut acc = [0.0f64; 4];
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (x, y) in ca.zip(cb) {
        let d0 = x[0] - y[0];
        let d1 = x[1] - y[1];
        let d2 = x[2] - y[2];
        let d3 = x[3] - y[3];
        acc[0] += d0 * d0;
        acc[1] += d1 * d1;
        acc[2] += d2 * d2;
        acc[3] += d3 * d3;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Four squared distances at once: test rows `a0..a3` against one
/// training row `b`. Each output replays [`sq_dist`]'s operation
/// sequence exactly (4-lane accumulation over chunks, lane sum, scalar
/// tail) so `sq_dist_x4(..)[t] == sq_dist(a_t, b)` bit for bit; the
/// win is that every chunk of `b` is loaded once for four outputs.
#[inline]
fn sq_dist_x4(a0: &[f64], a1: &[f64], a2: &[f64], a3: &[f64], b: &[f64]) -> [f64; 4] {
    debug_assert!(
        a0.len() == b.len() && a1.len() == b.len() && a2.len() == b.len() && a3.len() == b.len()
    );
    let mut acc = [[0.0f64; 4]; TILE_M];
    let c0 = a0.chunks_exact(4);
    let c1 = a1.chunks_exact(4);
    let c2 = a2.chunks_exact(4);
    let c3 = a3.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (r0, r1, r2, r3, rb) = (
        c0.remainder(),
        c1.remainder(),
        c2.remainder(),
        c3.remainder(),
        cb.remainder(),
    );
    for ((((x0, x1), x2), x3), y) in c0.zip(c1).zip(c2).zip(c3).zip(cb) {
        let y0 = y[0];
        let y1 = y[1];
        let y2 = y[2];
        let y3 = y[3];
        for (t, x) in [x0, x1, x2, x3].into_iter().enumerate() {
            let d0 = x[0] - y0;
            let d1 = x[1] - y1;
            let d2 = x[2] - y2;
            let d3 = x[3] - y3;
            acc[t][0] += d0 * d0;
            acc[t][1] += d1 * d1;
            acc[t][2] += d2 * d2;
            acc[t][3] += d3 * d3;
        }
    }
    let mut s = [0.0f64; TILE_M];
    for (t, ra) in [r0, r1, r2, r3].into_iter().enumerate() {
        s[t] = acc[t][0] + acc[t][1] + acc[t][2] + acc[t][3];
        for (x, y) in ra.iter().zip(rb) {
            let d = x - y;
            s[t] += d * d;
        }
    }
    s
}

/// Euclidean distance.
#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    sq_dist(a, b).sqrt()
}

/// Squared distances from `x` to every row of the `n x p` matrix `rows`;
/// output written into `out` (len n). Zero-allocation hot path.
pub fn dist_row_sq_into(x: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
    debug_assert_eq!(rows.len(), out.len() * p);
    for (i, o) in out.iter_mut().enumerate() {
        *o = sq_dist(x, &rows[i * p..(i + 1) * p]);
    }
}

/// Allocating convenience wrapper over [`dist_row_sq_into`].
pub fn dist_row_sq(x: &[f64], rows: &[f64], p: usize) -> Vec<f64> {
    let n = rows.len() / p;
    let mut out = vec![0.0; n];
    dist_row_sq_into(x, rows, p, &mut out);
    out
}

/// Full `m x n` squared-distance matrix between the rows of `xs`
/// (`m x p`, the test batch) and the rows of `rows` (`n x p`, the
/// training set), written row-major into `out` (len `m * n`).
///
/// Bit-identical to `m` stacked [`dist_row_sq_into`] calls — see the
/// module docs for the tiling scheme and the determinism contract.
pub fn dist_matrix_sq_into(xs: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
    if p == 0 {
        return;
    }
    let m = xs.len() / p;
    let n = rows.len() / p;
    debug_assert_eq!(xs.len(), m * p);
    debug_assert_eq!(rows.len(), n * p);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let block = (L1_BLOCK_F64 / p).max(1);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + block).min(n);
        let mut i0 = 0;
        while i0 + TILE_M <= m {
            let a0 = &xs[i0 * p..(i0 + 1) * p];
            let a1 = &xs[(i0 + 1) * p..(i0 + 2) * p];
            let a2 = &xs[(i0 + 2) * p..(i0 + 3) * p];
            let a3 = &xs[(i0 + 3) * p..(i0 + 4) * p];
            for j in j0..j1 {
                let d = sq_dist_x4(a0, a1, a2, a3, &rows[j * p..(j + 1) * p]);
                out[i0 * n + j] = d[0];
                out[(i0 + 1) * n + j] = d[1];
                out[(i0 + 2) * n + j] = d[2];
                out[(i0 + 3) * n + j] = d[3];
            }
            i0 += TILE_M;
        }
        // tail tile of < TILE_M test rows
        for i in i0..m {
            let xi = &xs[i * p..(i + 1) * p];
            for j in j0..j1 {
                out[i * n + j] = sq_dist(xi, &rows[j * p..(j + 1) * p]);
            }
        }
        j0 = j1;
    }
}

/// Allocating convenience wrapper over [`dist_matrix_sq_into`].
pub fn dist_matrix_sq(xs: &[f64], rows: &[f64], p: usize) -> Vec<f64> {
    let (m, n) = if p == 0 {
        (0, 0)
    } else {
        (xs.len() / p, rows.len() / p)
    };
    let mut out = vec![0.0; m * n];
    dist_matrix_sq_into(xs, rows, p, &mut out);
    out
}

/// [`dist_matrix_sq_into`] with the test-row tiles spread over
/// `workers` scoped threads (the shared-work-list pattern from
/// `bench_harness::timing::parallel_map`, promoted here).
///
/// Each job is a fixed (test-tile, output-tile) pair pulled from a
/// mutex-guarded iterator, so *which thread* computes a tile never
/// changes *where or what* it writes: output bytes are identical for
/// every worker count, including `workers == 1` (which short-circuits
/// to the serial kernel).
pub fn dist_matrix_sq_into_workers(
    xs: &[f64],
    rows: &[f64],
    p: usize,
    workers: usize,
    out: &mut [f64],
) {
    if p == 0 {
        return;
    }
    let m = xs.len() / p;
    let n = rows.len() / p;
    if m == 0 || n == 0 {
        return;
    }
    let jobs = m.div_ceil(PAR_TILE_M);
    let threads = workers.min(jobs);
    if threads <= 1 {
        dist_matrix_sq_into(xs, rows, p, out);
        return;
    }
    let queue = Mutex::new(xs.chunks(PAR_TILE_M * p).zip(out.chunks_mut(PAR_TILE_M * n)));
    // THREADS: `threads` scoped workers joined at scope exit; each owns
    // the disjoint output tile it pulls, so writes never alias.
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // LOCK-ORDER: linalg.tile_queue — innermost, held only
                // for the tile pop, dropped before computing.
                let job = queue.lock().unwrap().next();
                match job {
                    Some((xt, ot)) => dist_matrix_sq_into(xt, rows, p, ot),
                    None => break,
                }
            });
        }
    });
}

/// Full `n x n` squared-distance matrix over the rows of `a` (row-major
/// output). Exploits symmetry — computes the upper triangle through the
/// tiled matrix kernel (row tiles against the column suffix) and
/// mirrors, so every off-diagonal entry is still the exact
/// `sq_dist(row_i, row_j)` value for `i < j`.
pub fn pairwise_sq(a: &[f64], p: usize) -> Vec<f64> {
    let n = if p == 0 { 0 } else { a.len() / p };
    let mut out = vec![0.0; n * n];
    let mut buf: Vec<f64> = Vec::new();
    let mut i0 = 0;
    while i0 < n {
        let i1 = (i0 + PAR_TILE_M).min(n);
        let cols = n - i0;
        buf.clear();
        buf.resize((i1 - i0) * cols, 0.0);
        dist_matrix_sq_into(&a[i0 * p..i1 * p], &a[i0 * p..], p, &mut buf);
        for i in i0..i1 {
            let brow = &buf[(i - i0) * cols..(i - i0 + 1) * cols];
            for j in i + 1..n {
                let d = brow[j - i0];
                out[i * n + j] = d;
                out[j * n + i] = d;
            }
        }
        i0 = i1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_dist_known() {
        assert_eq!(sq_dist(&[0., 0.], &[3., 4.]), 25.0);
        assert_eq!(dist(&[0., 0.], &[3., 4.]), 5.0);
    }

    #[test]
    fn sq_dist_odd_lengths() {
        // exercise the non-multiple-of-4 tail
        for len in [1, 2, 3, 5, 7, 9] {
            let a: Vec<f64> = (0..len).map(|i| i as f64).collect();
            let b = vec![0.0; len];
            let want: f64 = (0..len).map(|i| (i * i) as f64).sum();
            assert_eq!(sq_dist(&a, &b), want);
        }
    }

    #[test]
    fn row_matches_pointwise() {
        let rows = vec![1., 2., 3., 4., 5., 6.]; // 3 x 2
        let x = vec![0., 0.];
        let d = dist_row_sq(&x, &rows, 2);
        assert_eq!(d, vec![5., 25., 61.]);
    }

    #[test]
    fn pairwise_symmetric_zero_diag() {
        let a = vec![0., 0., 1., 0., 0., 2.]; // 3 x 2
        let m = pairwise_sq(&a, 2);
        assert_eq!(m[0 * 3 + 0], 0.0);
        assert_eq!(m[0 * 3 + 1], 1.0);
        assert_eq!(m[1 * 3 + 0], 1.0);
        assert_eq!(m[1 * 3 + 2], 5.0);
        assert_eq!(m[2 * 3 + 1], 5.0);
    }

    fn fill(seed: u64, len: usize) -> Vec<f64> {
        let mut s = seed.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s >> 11) as f64 / (1u64 << 53) as f64 - 0.5
            })
            .collect()
    }

    fn stacked_rows(xs: &[f64], rows: &[f64], p: usize) -> Vec<f64> {
        let m = xs.len() / p;
        let n = rows.len() / p;
        let mut want = vec![0.0; m * n];
        for i in 0..m {
            dist_row_sq_into(&xs[i * p..(i + 1) * p], rows, p, &mut want[i * n..(i + 1) * n]);
        }
        want
    }

    #[test]
    fn matrix_bitwise_equals_stacked_rows() {
        // shapes straddling the TILE_M and L1 block boundaries
        for (m, n, p) in [
            (1, 1, 1),
            (3, 5, 2),
            (4, 4, 4),
            (5, 7, 3),
            (9, 2, 5),
            (2, 9, 6),
            (17, 33, 7),
        ] {
            let xs = fill(m as u64 * 31 + n as u64, m * p);
            let rows = fill(n as u64 * 17 + p as u64, n * p);
            let got = dist_matrix_sq(&xs, &rows, p);
            let want = stacked_rows(&xs, &rows, p);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "m={m} n={n} p={p}");
            }
        }
    }

    #[test]
    fn matrix_empty_shapes() {
        let mut out = vec![];
        dist_matrix_sq_into(&[], &[1.0, 2.0], 2, &mut out);
        dist_matrix_sq_into(&[1.0, 2.0], &[], 2, &mut out);
        assert!(dist_matrix_sq(&[], &[], 3).is_empty());
    }

    #[test]
    fn workers_do_not_change_bytes() {
        let (m, n, p) = (21, 13, 3);
        let xs = fill(5, m * p);
        let rows = fill(6, n * p);
        let serial = dist_matrix_sq(&xs, &rows, p);
        for workers in [1, 2, 4, 9] {
            let mut out = vec![0.0; m * n];
            dist_matrix_sq_into_workers(&xs, &rows, p, workers, &mut out);
            for (g, w) in out.iter().zip(&serial) {
                assert_eq!(g.to_bits(), w.to_bits(), "workers={workers}");
            }
        }
    }

    #[test]
    fn pairwise_matches_naive_double_loop() {
        let n = 11;
        let p = 3;
        let a = fill(42, n * p);
        let m = pairwise_sq(&a, p);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j {
                    0.0
                } else {
                    sq_dist(&a[i * p..(i + 1) * p], &a[j * p..(j + 1) * p])
                };
                assert_eq!(m[i * n + j].to_bits(), want.to_bits());
            }
        }
    }
}
