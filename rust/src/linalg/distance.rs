//! Distance kernels — the compute hot-spot of every nearest-neighbour
//! family measure (native CPU implementations; `runtime::PjrtBackend`
//! provides the AOT/PJRT-executed alternative for the same entry points).

/// Which engine computes distance rows/matrices.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// Hand-written Rust loops (default; fastest on this 1-core testbed).
    #[default]
    Native,
    /// AOT-compiled Pallas/JAX kernels executed via the PJRT C API.
    Pjrt,
}

/// Squared Euclidean distance between two vectors.
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // chunks_exact gives the compiler bounds-check-free, SIMD-friendly
    // bodies (§Perf: measurably better than manual indexing).
    let mut acc = [0.0f64; 4];
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (x, y) in ca.zip(cb) {
        let d0 = x[0] - y[0];
        let d1 = x[1] - y[1];
        let d2 = x[2] - y[2];
        let d3 = x[3] - y[3];
        acc[0] += d0 * d0;
        acc[1] += d1 * d1;
        acc[2] += d2 * d2;
        acc[3] += d3 * d3;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Euclidean distance.
#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    sq_dist(a, b).sqrt()
}

/// Squared distances from `x` to every row of the `n x p` matrix `rows`;
/// output written into `out` (len n). Zero-allocation hot path.
pub fn dist_row_sq_into(x: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
    debug_assert_eq!(rows.len(), out.len() * p);
    for (i, o) in out.iter_mut().enumerate() {
        *o = sq_dist(x, &rows[i * p..(i + 1) * p]);
    }
}

/// Allocating convenience wrapper over [`dist_row_sq_into`].
pub fn dist_row_sq(x: &[f64], rows: &[f64], p: usize) -> Vec<f64> {
    let n = rows.len() / p;
    let mut out = vec![0.0; n];
    dist_row_sq_into(x, rows, p, &mut out);
    out
}

/// Full `n x n` squared-distance matrix over the rows of `a` (row-major
/// output). Exploits symmetry: computes the upper triangle and mirrors.
pub fn pairwise_sq(a: &[f64], p: usize) -> Vec<f64> {
    let n = a.len() / p;
    let mut out = vec![0.0; n * n];
    for i in 0..n {
        let ri = &a[i * p..(i + 1) * p];
        for j in i + 1..n {
            let d = sq_dist(ri, &a[j * p..(j + 1) * p]);
            out[i * n + j] = d;
            out[j * n + i] = d;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_dist_known() {
        assert_eq!(sq_dist(&[0., 0.], &[3., 4.]), 25.0);
        assert_eq!(dist(&[0., 0.], &[3., 4.]), 5.0);
    }

    #[test]
    fn sq_dist_odd_lengths() {
        // exercise the non-multiple-of-4 tail
        for len in [1, 2, 3, 5, 7, 9] {
            let a: Vec<f64> = (0..len).map(|i| i as f64).collect();
            let b = vec![0.0; len];
            let want: f64 = (0..len).map(|i| (i * i) as f64).sum();
            assert_eq!(sq_dist(&a, &b), want);
        }
    }

    #[test]
    fn row_matches_pointwise() {
        let rows = vec![1., 2., 3., 4., 5., 6.]; // 3 x 2
        let x = vec![0., 0.];
        let d = dist_row_sq(&x, &rows, 2);
        assert_eq!(d, vec![5., 25., 61.]);
    }

    #[test]
    fn pairwise_symmetric_zero_diag() {
        let a = vec![0., 0., 1., 0., 0., 2.]; // 3 x 2
        let m = pairwise_sq(&a, 2);
        assert_eq!(m[0 * 3 + 0], 0.0);
        assert_eq!(m[0 * 3 + 1], 1.0);
        assert_eq!(m[1 * 3 + 0], 1.0);
        assert_eq!(m[1 * 3 + 2], 5.0);
        assert_eq!(m[2 * 3 + 1], 5.0);
    }
}
