//! App. C.5 driver: cost of the online IID test (Vovk et al. 2003).
//!
//! Processing a stream of N observations with k-NN CP costs O(N^3)
//! standard (each step's p-value is recomputed from scratch) vs O(N^2)
//! with the optimized incremental measure. The driver measures
//! cumulative time at checkpoints for both, plus a martingale
//! change-detection demo.

use anyhow::Result;

use crate::bench_harness::report::{fmt_secs, Report};
use crate::bench_harness::timing::loglog_slope;
use crate::config::Config;
use crate::cp::measure::CpMeasure;
use crate::cp::pvalue::smoothed_p_value;
use crate::data::{Dataset, Rng};
use crate::measures::knn::{KnnOptimized, KnnStandard};
use crate::online::ExchangeabilityTest;

fn stream(n: usize, dim: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.normal()).collect())
        .collect()
}

pub fn run_iid(cfg: &Config) -> Result<Report> {
    let k = cfg.measure.k.min(5);
    let dim = 5;
    let n_opt = if cfg.experiment.paper_scale { 4000 } else { 800 };
    let n_std = (n_opt / 4).max(100);
    let checkpoints = |n: usize| -> Vec<usize> {
        (1..=8).map(|i| n * i / 8).collect()
    };

    let mut report = Report::new(
        "iid",
        "online IID test (Vovk 2003): cumulative processing time",
        &["method", "stream_len", "cumulative_s"],
    );

    // optimized: incremental learn via the optimized measure
    {
        let xs = stream(n_opt, dim, 1);
        let mut t = ExchangeabilityTest::new(KnnOptimized::new(k, true), dim, 2);
        let cps = checkpoints(n_opt);
        let t0 = std::time::Instant::now();
        for (i, x) in xs.iter().enumerate() {
            t.observe(x);
            if cps.contains(&(i + 1)) {
                report.push_row(vec![
                    "optimized".into(),
                    (i + 1).to_string(),
                    format!("{:.4}", t0.elapsed().as_secs_f64()),
                ]);
            }
        }
        println!("  [iid] optimized stream of {n_opt} done");
    }

    // standard: refit KnnStandard on the growing prefix at every step
    {
        let xs = stream(n_std, dim, 1);
        let cps = checkpoints(n_std);
        let t0 = std::time::Instant::now();
        let mut rng = Rng::seed_from(3);
        let mut seen: Vec<f64> = Vec::new();
        for (i, x) in xs.iter().enumerate() {
            if i > 0 {
                let ds = Dataset::new(seen.clone(), vec![0; i], dim, 1);
                let mut m = KnnStandard::new(k, true);
                m.fit(&ds);
                let s = m.scores(x, 0);
                let _ = smoothed_p_value(&s, rng.f64());
            }
            seen.extend_from_slice(x);
            if cps.contains(&(i + 1)) {
                report.push_row(vec![
                    "standard".into(),
                    (i + 1).to_string(),
                    format!("{:.4}", t0.elapsed().as_secs_f64()),
                ]);
            }
        }
        println!("  [iid] standard stream of {n_std} done");
    }

    // growth-exponent summary
    let slope_of = |method: &str| -> f64 {
        let pts: Vec<(f64, f64)> = report
            .rows
            .iter()
            .filter(|r| r[0] == method)
            .map(|r| (r[1].parse().unwrap(), r[2].parse().unwrap()))
            .collect();
        let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
        loglog_slope(&xs, &ys)
    };
    let s_opt = slope_of("optimized");
    let s_std = slope_of("standard");
    report.note(&format!(
        "measured cumulative-cost exponents: optimized ~n^{s_opt:.2} \
         (analytic 2), standard ~n^{s_std:.2} (analytic 3). Last \
         checkpoint wall-times: optimized {}, standard {} (at 1/4 the \
         stream length).",
        fmt_secs(
            report
                .rows
                .iter()
                .filter(|r| r[0] == "optimized")
                .last()
                .map(|r| r[2].parse().unwrap())
                .unwrap_or(f64::NAN)
        ),
        fmt_secs(
            report
                .rows
                .iter()
                .filter(|r| r[0] == "standard")
                .last()
                .map(|r| r[2].parse().unwrap())
                .unwrap_or(f64::NAN)
        ),
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_smoke() {
        let mut cfg = Config::default();
        cfg.measure.k = 3;
        cfg.experiment.paper_scale = false;
        // shrink further for test speed by running the pieces directly
        let xs = stream(60, 3, 9);
        let mut t = ExchangeabilityTest::new(KnnOptimized::new(3, true), 3, 10);
        for x in &xs {
            t.observe(x);
        }
        assert_eq!(t.p_values.len(), 59);
        let _ = cfg;
    }
}
