//! Table 3 driver (App. H): sequential vs parallel CP classification.
//!
//! The paper parallelizes Algorithm 1 over (label x test point) with a
//! Python process pool on a 48-thread Xeon. Here the parallel version
//! uses an in-process thread pool over test points. On this 1-core
//! testbed the *overhead* side of the paper's finding is what
//! reproduces: for small data / cheap optimized measures,
//! parallelization does not pay (the paper's surprising optimized-k-NN
//! row); thread counts are configurable for multi-core runs.

use std::time::Duration;

use anyhow::Result;

use crate::bench_harness::report::{fmt_secs, Report};
use crate::bench_harness::timing::{parallel_map, time_once};
use crate::config::{Config, MeasureKind};
use crate::coordinator::factory::{build_measure, build_standard_measure};
use crate::cp::pvalue::p_value;
use crate::data::{make_classification, ClassificationSpec};

pub fn run_table3(cfg: &Config) -> Result<Report> {
    let n = if cfg.experiment.train_sizes.is_empty() {
        1000
    } else {
        cfg.experiment.train_sizes[0]
    };
    let n_test = cfg.experiment.n_test.max(4);
    let threads = 4usize;
    let timeout = Duration::from_secs_f64(cfg.experiment.timeout_s);

    let all = make_classification(
        &ClassificationSpec {
            n_samples: n + n_test,
            ..Default::default()
        },
        7,
    );
    let mut rng = crate::data::Rng::seed_from(8);
    let (train, test) = all.split(n, &mut rng);

    let mut report = Report::new(
        "table3",
        "sequential vs parallel CP (App. H), time for the whole test batch",
        &["variant", "measure", "sequential", "parallel", "speedup"],
    );

    let kinds = [
        MeasureKind::SimplifiedKnn,
        MeasureKind::Knn,
        MeasureKind::Kde,
        MeasureKind::LsSvm,
        MeasureKind::RandomForest,
    ];
    for standard in [true, false] {
        for kind in kinds {
            // standard RF/LS-SVM at n=1000 are hours-scale; bound them
            let (n_eff, n_test_eff) = if standard
                && matches!(
                    kind,
                    MeasureKind::RandomForest | MeasureKind::LsSvm
                ) {
                (n.min(200), n_test.min(4))
            } else {
                (n, n_test)
            };
            let train_eff = train.subset(&(0..n_eff.min(train.n())).collect::<Vec<_>>());
            let mut m = if standard {
                build_standard_measure(kind, &cfg.measure)
            } else {
                build_measure(kind, &cfg.measure, None)
            };
            m.fit(&train_eff);
            let m = &m;

            let work = |i: usize| {
                for y in 0..train_eff.n_labels {
                    let _ = p_value(&m.scores(test.row(i), y));
                }
            };
            let (_, seq_s) = time_once(|| {
                for i in 0..n_test_eff {
                    work(i);
                }
            });
            if Duration::from_secs_f64(seq_s) > timeout * 4 {
                // hopeless cell; record sequential only
                report.push_row(vec![
                    if standard { "standard" } else { "optimized" }.into(),
                    kind.as_str().into(),
                    fmt_secs(seq_s),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
            let (_, par_s) =
                time_once(|| parallel_map(n_test_eff, threads, |i| work(i)));
            report.push_row(vec![
                if standard { "standard" } else { "optimized" }.into(),
                kind.as_str().into(),
                fmt_secs(seq_s),
                fmt_secs(par_s),
                format!("{:.2}x", seq_s / par_s),
            ]);
            println!(
                "  [table3] {}/{} done",
                if standard { "standard" } else { "optimized" },
                kind.as_str()
            );
        }
    }
    report.note(&format!(
        "threads = {threads}; testbed has {} hardware core(s). Paper \
         reference (Table 3, 48 threads): standard CP gains ~20x from \
         parallelism; optimized measures gain little (optimized k-NN was \
         *slower* parallel) — per-task overhead dominates cheap tasks.",
        std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(1)
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_smoke() {
        let mut cfg = Config::default();
        cfg.experiment.train_sizes = vec![60];
        cfg.experiment.n_test = 4;
        cfg.experiment.timeout_s = 30.0;
        cfg.measure.k = 3;
        cfg.measure.b = 3;
        let r = run_table3(&cfg).unwrap();
        assert_eq!(r.rows.len(), 10);
    }
}
