//! Experiment harness: one driver per table/figure of the paper's
//! evaluation (DESIGN.md §4 maps each to its module), shared timing
//! infrastructure, and CSV/markdown report emission.
//!
//! | id          | paper          | driver                         |
//! |-------------|----------------|--------------------------------|
//! | `fig2`      | Figure 2       | [`classification`]             |
//! | `fig3`      | Figure 3       | [`classification`]             |
//! | `fig4`      | Figure 4       | [`regression_exp`]             |
//! | `fig5`      | Figure 5       | [`bootstrap_exp`]              |
//! | `fig6`      | Figure 6       | [`classification`]             |
//! | `table1`    | Table 1        | [`classification`] (slope fit) |
//! | `table2`    | Table 2        | [`mnist_exp`]                  |
//! | `fuzziness` | App. G table   | [`mnist_exp`]                  |
//! | `table3`    | Table 3        | [`parallel_exp`]               |
//! | `iid`       | App. C.5       | [`iid_exp`]                    |

pub mod bootstrap_exp;
pub mod classification;
pub mod iid_exp;
pub mod mnist_exp;
pub mod parallel_exp;
pub mod regression_exp;
pub mod report;
pub mod timing;

use anyhow::{bail, Result};

use crate::config::Config;
pub use report::Report;

/// All experiment ids, in suggested execution order (cheap first).
pub const ALL_EXPERIMENTS: [&str; 10] = [
    "fig5", "table1", "iid", "fig4", "fig6", "fig2", "fig3", "table3",
    "fuzziness", "table2",
];

/// Run one experiment by id, writing its reports to the configured
/// output directory, and returning the report.
pub fn run_experiment(id: &str, cfg: &Config) -> Result<Report> {
    let report = match id {
        "fig2" => classification::run_prediction_figure("fig2", cfg)?,
        "fig6" => classification::run_prediction_figure("fig6", cfg)?,
        "fig3" => classification::run_training_figure(cfg)?,
        "table1" => classification::run_table1(cfg)?,
        "fig4" => regression_exp::run_fig4(cfg)?,
        "fig5" => bootstrap_exp::run_fig5(cfg)?,
        "table2" => mnist_exp::run_table2(cfg)?,
        "fuzziness" => mnist_exp::run_fuzziness(cfg)?,
        "table3" => parallel_exp::run_table3(cfg)?,
        "iid" => iid_exp::run_iid(cfg)?,
        other => bail!(
            "unknown experiment {other:?}; known: {}",
            ALL_EXPERIMENTS.join(", ")
        ),
    };
    report.write(&cfg.experiment.out_dir)?;
    Ok(report)
}
