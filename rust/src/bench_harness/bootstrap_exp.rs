//! Figure 5 driver (App. C.4): the relation between the requested
//! ensemble size B, the training size n, and the number of bootstrap
//! samples B' the optimized algorithm actually draws before every point
//! (and the placeholder "*") is excluded from at least B samples.

use anyhow::Result;

use crate::bench_harness::report::Report;
use crate::config::Config;
use crate::data::{make_classification, ClassificationSpec};
use crate::measures::bootstrap::{BootstrapOptimized, BootstrapParams};
use crate::measures::tree::TreeParams;
use crate::cp::measure::CpMeasure;

pub fn run_fig5(cfg: &Config) -> Result<Report> {
    let exp = &cfg.experiment;
    let sizes = if exp.train_sizes.is_empty() {
        vec![10, 32, 100, 316, 1000, 3162]
    } else {
        exp.train_sizes.clone()
    };
    let bs = [5usize, 10, 20];
    let mut report = Report::new(
        "fig5",
        "optimized bootstrap: drawn samples B' vs requested B and n",
        &["B", "n", "seed", "B_prime", "ratio_Bp_over_B"],
    );
    for &b in &bs {
        for &n in &sizes {
            for seed in 0..exp.seeds {
                let ds = make_classification(
                    &ClassificationSpec {
                        n_samples: n,
                        ..Default::default()
                    },
                    500 + seed,
                );
                // fit with stumps: fig5 only measures the sampling loop,
                // so keep tree cost negligible
                let mut m = BootstrapOptimized::new(BootstrapParams {
                    b,
                    tree: TreeParams {
                        max_depth: 1,
                        ..Default::default()
                    },
                    seed,
                });
                m.fit(&ds);
                report.push_row(vec![
                    b.to_string(),
                    n.to_string(),
                    seed.to_string(),
                    m.b_prime.to_string(),
                    format!("{:.2}", m.b_prime as f64 / b as f64),
                ]);
            }
        }
        println!("  [fig5] finished B = {}", b);
    }
    report.note(
        "Paper reference (Fig. 5): B' grows slowly with n and stays far \
         below B*n — each drawn sample excludes ~n/e points at once, so \
         samples are shared across many E_i sets. Expected B'/B ~ e/(1) \
         * (1 + o(1)) * ln-ish growth in n.",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_smoke_and_shape() {
        let mut cfg = Config::default();
        cfg.experiment.train_sizes = vec![16, 128];
        cfg.experiment.seeds = 1;
        let r = run_fig5(&cfg).unwrap();
        assert_eq!(r.rows.len(), 3 * 2);
        // B' >= B always; and B' << B*n at the larger n
        for row in &r.rows {
            let b: usize = row[0].parse().unwrap();
            let n: usize = row[1].parse().unwrap();
            let bp: usize = row[3].parse().unwrap();
            assert!(bp >= b, "B'={bp} < B={b}");
            assert!(bp < b * n, "B'={bp} not << B*n={}", b * n);
        }
    }
}
