//! Figure 4 driver: k-NN CP regression timing — Papadopoulos et al.
//! (2011) vs our incremental&decremental optimization vs ICP (§8.1).

use std::time::Duration;

use anyhow::Result;

use crate::bench_harness::report::Report;
use crate::bench_harness::timing::{time_once, time_sweep};
use crate::config::Config;
use crate::data::{make_regression, RegressionSpec};
use crate::regression::{
    IcpKnnRegressor, KnnRegressorOptimized, KnnRegressorStandard,
};

pub fn run_fig4(cfg: &Config) -> Result<Report> {
    let exp = &cfg.experiment;
    let sizes = if exp.train_sizes.is_empty() {
        super::classification::default_grid(exp.paper_scale)
    } else {
        exp.train_sizes.clone()
    };
    let timeout = Duration::from_secs_f64(exp.timeout_s);
    let k = cfg.measure.k;
    let mut report = Report::new(
        "fig4",
        "k-NN CP regression: Papadopoulos-2011 vs optimized vs ICP",
        &[
            "method", "n", "seed", "train_s", "avg_predict_s", "completed",
            "timed_out",
        ],
    );
    let mut dead: std::collections::HashSet<&'static str> = Default::default();
    for &n in &sizes {
        if n < k + 2 {
            continue;
        }
        for seed in 0..exp.seeds {
            let spec = RegressionSpec {
                n_samples: n,
                n_features: 30,
                n_informative: 10,
                noise: 10.0,
            };
            let ds = make_regression(&spec, 100 + seed);
            let probe = make_regression(
                &RegressionSpec {
                    n_samples: exp.n_test.max(1),
                    ..spec.clone()
                },
                200 + seed,
            );

            // Papadopoulos-2011 (the "standard" full CP regressor)
            if !dead.contains("papadopoulos2011") {
                let mut m = KnnRegressorStandard::new(k);
                let (_, train_s) = time_once(|| m.fit(&ds));
                let sweep = time_sweep(probe.n(), timeout, |i| {
                    let _ = m.predict_region(probe.row(i), 0.1);
                });
                push(&mut report, "papadopoulos2011", n, seed, train_s, &sweep);
                if sweep.timed_out && seed + 1 == exp.seeds {
                    dead.insert("papadopoulos2011");
                }
            }

            // our optimization
            if !dead.contains("optimized") {
                let mut m = KnnRegressorOptimized::new(k);
                let (_, train_s) = time_once(|| m.fit(&ds));
                let sweep = time_sweep(probe.n(), timeout, |i| {
                    let _ = m.predict_region(probe.row(i), 0.1);
                });
                push(&mut report, "optimized", n, seed, train_s, &sweep);
                if sweep.timed_out && seed + 1 == exp.seeds {
                    dead.insert("optimized");
                }
            }

            // ICP baseline
            {
                let mut m = IcpKnnRegressor::new(k);
                let t = (n / 2).max(1);
                let (_, train_s) = time_once(|| m.fit(&ds, t));
                let sweep = time_sweep(probe.n(), timeout, |i| {
                    let _ = m.predict_interval(probe.row(i), 0.1);
                });
                push(&mut report, "icp", n, seed, train_s, &sweep);
            }
        }
        println!("  [fig4] finished n = {}", n);
    }
    report.note(
        "Paper reference (Fig. 4, n = 1e5): Papadopoulos-2011 ~1 h per \
         prediction, ours 9.3 s, ICP 4.6 ms. Shape target: ours sits ~1 \
         power of n below the 2011 method; ICP flat.",
    );
    Ok(report)
}

fn push(
    report: &mut Report,
    method: &str,
    n: usize,
    seed: u64,
    train_s: f64,
    sweep: &crate::bench_harness::timing::SweepTiming,
) {
    report.push_row(vec![
        method.into(),
        n.to_string(),
        seed.to_string(),
        format!("{train_s:.6}"),
        sweep
            .avg()
            .map(|a| format!("{a:.6}"))
            .unwrap_or_default(),
        sweep.completed().to_string(),
        sweep.timed_out.to_string(),
    ]);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_smoke() {
        let mut cfg = Config::default();
        cfg.experiment.train_sizes = vec![32, 64];
        cfg.experiment.n_test = 2;
        cfg.experiment.seeds = 1;
        cfg.measure.k = 3;
        let r = run_fig4(&cfg).unwrap();
        assert_eq!(r.rows.len(), 2 * 3);
    }
}
