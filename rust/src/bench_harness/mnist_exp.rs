//! Table 2 + App. G fuzziness drivers: the MNIST-scale evaluation.
//!
//! The environment has no network, so the workload is the MNIST-like
//! generator (784 features, 10 balanced classes — DESIGN.md §5 records
//! the substitution). Default sizes are scaled for the 1-core budget;
//! `--paper-scale` requests the full 60k/10k split.
//!
//! Two outputs:
//!   * `table2`    — training / prediction wall-times per measure for
//!     CP (standard & optimized) and ICP, with timeout markers;
//!   * `fuzziness` — statistical efficiency: mean +- std fuzziness of
//!     full CP vs ICP with a one-sided Welch test (H0: ICP better).

use std::time::Duration;

use anyhow::Result;

use crate::bench_harness::classification::{run_cell, Variant};
use crate::bench_harness::report::{fmt_secs, Report};
use crate::config::{Config, MeasureKind};
use crate::coordinator::factory::build_measure;
use crate::cp::icp::Icp;
use crate::cp::metrics::{fuzziness, mean_std, welch_one_sided};
use crate::cp::pvalue::p_value;
use crate::data::{mnist_like, Rng};
use crate::measures::IcpKnn;

fn sizes(cfg: &Config) -> (usize, usize) {
    if cfg.experiment.paper_scale {
        (60_000, 10_000)
    } else {
        (1_500, cfg.experiment.n_test.max(30))
    }
}

/// Table 2: wall-times on the MNIST-like workload.
pub fn run_table2(cfg: &Config) -> Result<Report> {
    let (n_train, n_test) = sizes(cfg);
    let timeout = Duration::from_secs_f64(cfg.experiment.timeout_s);
    let all = mnist_like(n_train + n_test, 42);
    let mut rng = Rng::seed_from(43);
    let (train, test) = all.split(n_train, &mut rng);

    let mut report = Report::new(
        "table2",
        "MNIST-like evaluation: train / prediction time (T = timed out)",
        &["measure", "variant", "train_time", "predict_time_total", "completed", "timed_out"],
    );
    // Paper Table 2 evaluates NN (k=1), Simplified k-NN, k-NN, KDE, RF.
    let cells: Vec<(MeasureKind, usize)> = vec![
        (MeasureKind::SimplifiedKnn, 1), // "NN" row: k = 1
        (MeasureKind::SimplifiedKnn, cfg.measure.k),
        (MeasureKind::Knn, cfg.measure.k),
        (MeasureKind::Kde, cfg.measure.k),
        (MeasureKind::RandomForest, cfg.measure.k),
    ];
    for (i, (kind, k)) in cells.iter().enumerate() {
        let mut c = cfg.clone();
        c.measure.k = *k;
        let label = if i == 0 {
            "nn(k=1)".to_string()
        } else {
            kind.as_str().to_string()
        };
        // standard CP is only run at paper scale when explicitly asked:
        // at 60k x 784 it predicts ~1 point in 48 h (that IS the paper's
        // row); at scaled sizes we run it with the configured timeout.
        for variant in [Variant::Standard, Variant::Optimized, Variant::Icp] {
            if variant == Variant::Standard
                && (*kind == MeasureKind::RandomForest || n_train > 3000)
            {
                // the paper's Table 2 itself reports T(0)/T(1) here;
                // skip to keep the driver bounded.
                report.push_row(vec![
                    label.clone(),
                    variant.as_str().into(),
                    "0s".into(),
                    "T(-)".into(),
                    "0".into(),
                    "true".into(),
                ]);
                continue;
            }
            let (train_s, avg, done, timed_out) =
                run_cell(*kind, variant, &train, &test, &c, timeout);
            let total = avg.map(|a| a * done as f64).unwrap_or(f64::INFINITY);
            report.push_row(vec![
                label.clone(),
                variant.as_str().into(),
                fmt_secs(train_s),
                if timed_out {
                    format!("T({done})")
                } else {
                    fmt_secs(total)
                },
                done.to_string(),
                timed_out.to_string(),
            ]);
            println!("  [table2] {label}/{} done", variant.as_str());
        }
    }
    report.note(&format!(
        "Scaled workload: {n_train} train / {n_test} test, 784 features, \
         10 labels (paper: 60k/10k with 48 h timeout). Paper reference: \
         standard CP finishes <=1 prediction; optimized Simplified k-NN \
         4.6 h vs ICP 1.6 h; optimized CP is practical, ICP remains \
         faster."
    ));
    Ok(report)
}

/// App. G: fuzziness of full CP vs ICP + one-sided Welch test.
pub fn run_fuzziness(cfg: &Config) -> Result<Report> {
    let (n_train, n_test) = sizes(cfg);
    // fuzziness needs enough test points for a meaningful Welch test
    let n_test = n_test.max(150);
    let all = mnist_like(n_train + n_test, 142);
    let mut rng = Rng::seed_from(143);
    let (train, test) = all.split(n_train, &mut rng);

    let mut report = Report::new(
        "fuzziness",
        "statistical efficiency on MNIST-like data: fuzziness (lower = better), Welch H0 'ICP <= CP'",
        &[
            "measure",
            "cp_fuzziness",
            "icp_fuzziness",
            "welch_t",
            "welch_p",
            "cp_wins_significant",
        ],
    );

    let cells: Vec<(MeasureKind, usize, String)> = vec![
        (MeasureKind::SimplifiedKnn, 1, "nn(k=1)".into()),
        (MeasureKind::SimplifiedKnn, cfg.measure.k, "simplified-knn".into()),
        (MeasureKind::Knn, cfg.measure.k, "knn".into()),
        (MeasureKind::Kde, cfg.measure.k, "kde".into()),
    ];
    for (kind, k, label) in cells {
        let mut mc = cfg.measure.clone();
        mc.k = k;
        // full CP p-values (optimized measure — exact full CP)
        let mut cp_measure = build_measure(kind, &mc, None);
        cp_measure.fit(&train);
        let cp_fuzz: Vec<f64> = (0..test.n())
            .map(|i| {
                let ps: Vec<f64> = (0..train.n_labels)
                    .map(|y| p_value(&cp_measure.scores(test.row(i), y)))
                    .collect();
                fuzziness(&ps)
            })
            .collect();
        // ICP p-values (same nonconformity family, t = n/2)
        let icp = match kind {
            MeasureKind::SimplifiedKnn => {
                Icp::calibrate(IcpKnn::new(k, true), &train, train.n() / 2)
            }
            MeasureKind::Knn => {
                Icp::calibrate(IcpKnn::new(k, false), &train, train.n() / 2)
            }
            MeasureKind::Kde => {
                // reuse the generic path through IcpKnn is wrong; build KDE
                return_kde_fuzziness(
                    &mut report,
                    &label,
                    &train,
                    &test,
                    &cp_fuzz,
                    cfg,
                )?;
                println!("  [fuzziness] {label} done");
                continue;
            }
            _ => unreachable!(),
        };
        let icp_fuzz: Vec<f64> = (0..test.n())
            .map(|i| fuzziness(&icp.p_values(test.row(i))))
            .collect();
        push_fuzz_row(&mut report, &label, &cp_fuzz, &icp_fuzz);
        println!("  [fuzziness] {label} done");
    }
    report.note(
        "Paper reference (App. G): full CP has significantly smaller \
         fuzziness than ICP for every measure (asterisked rows). The \
         Welch column tests H0 'ICP is at least as good'; p < 0.01 \
         reproduces the paper's asterisk.",
    );
    Ok(report)
}

fn return_kde_fuzziness(
    report: &mut Report,
    label: &str,
    train: &crate::data::Dataset,
    test: &crate::data::Dataset,
    cp_fuzz: &[f64],
    cfg: &Config,
) -> Result<()> {
    use crate::measures::IcpKde;
    let icp = Icp::calibrate(IcpKde::new(cfg.measure.h), train, train.n() / 2);
    let icp_fuzz: Vec<f64> = (0..test.n())
        .map(|i| fuzziness(&icp.p_values(test.row(i))))
        .collect();
    push_fuzz_row(report, label, cp_fuzz, &icp_fuzz);
    Ok(())
}

fn push_fuzz_row(report: &mut Report, label: &str, cp: &[f64], icp: &[f64]) {
    let (mc, sc) = mean_std(cp);
    let (mi, si) = mean_std(icp);
    // H0: ICP better (smaller) — i.e. test whether mean(cp) < mean(icp)
    let (t, p) = welch_one_sided(cp, icp);
    report.push_row(vec![
        label.into(),
        format!("{mc:.5} ± {sc:.5}"),
        format!("{mi:.5} ± {si:.5}"),
        format!("{t:.2}"),
        format!("{p:.2e}"),
        (p < 0.01 && mc < mi).to_string(),
    ]);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Config {
        let mut c = Config::default();
        c.experiment.n_test = 10;
        c.experiment.timeout_s = 10.0;
        c.measure.k = 3;
        c.measure.b = 5;
        c
    }

    #[test]
    fn fuzziness_smoke() {
        // shrink by monkey-patching scale via paper_scale=false default
        let mut cfg = tiny();
        // override internal sizes through a tiny custom run:
        cfg.experiment.n_test = 10;
        // run with very small mnist-like data by calling the pieces
        let all = mnist_like(120, 1);
        let mut rng = Rng::seed_from(2);
        let (train, test) = all.split(100, &mut rng);
        let mut m = build_measure(MeasureKind::SimplifiedKnn, &cfg.measure, None);
        m.fit(&train);
        let fz: Vec<f64> = (0..test.n())
            .map(|i| {
                let ps: Vec<f64> = (0..10)
                    .map(|y| p_value(&m.scores(test.row(i), y)))
                    .collect();
                fuzziness(&ps)
            })
            .collect();
        assert_eq!(fz.len(), 20);
        assert!(fz.iter().all(|&f| (0.0..=10.0).contains(&f)));
    }
}
