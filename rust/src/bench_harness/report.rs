//! Report emission: every experiment driver writes a CSV (machine
//! readable) and a markdown table (human readable) into the configured
//! output directory, and EXPERIMENTS.md links to them.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

/// A tabular report under construction.
#[derive(Clone, Debug)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// free-form notes rendered under the markdown table
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Self {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn note(&mut self, text: &str) {
        self.notes.push(text.to_string());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.columns.join(","));
        for row in &self.rows {
            let quoted: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            let _ = writeln!(out, "{}", quoted.join(","));
        }
        out
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# {} — {}\n", self.id, self.title);
        let _ = writeln!(out, "| {} |", self.columns.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        for note in &self.notes {
            let _ = writeln!(out, "\n> {note}");
        }
        out
    }

    /// Write `<out_dir>/<id>.csv` and `<out_dir>/<id>.md`.
    pub fn write(&self, out_dir: &str) -> Result<()> {
        fs::create_dir_all(out_dir)
            .with_context(|| format!("creating {out_dir}"))?;
        let base = Path::new(out_dir);
        fs::write(base.join(format!("{}.csv", self.id)), self.to_csv())?;
        fs::write(base.join(format!("{}.md", self.id)), self.to_markdown())?;
        println!(
            "wrote {}/{}.csv ({} rows)",
            out_dir,
            self.id,
            self.rows.len()
        );
        Ok(())
    }
}

/// Format seconds in engineering style (matches how the paper reports
/// "0.63 seconds" / "2 hours").
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "-".into();
    }
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else if s < 7200.0 {
        format!("{:.1}m", s / 60.0)
    } else {
        format!("{:.1}h", s / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_markdown_shape() {
        let mut r = Report::new("t", "demo", &["a", "b"]);
        r.push_row(vec!["1".into(), "x,y".into()]);
        r.note("hello");
        let csv = r.to_csv();
        assert!(csv.starts_with("a,b\n"));
        assert!(csv.contains("\"x,y\""));
        let md = r.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("> hello"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut r = Report::new("t", "demo", &["a", "b"]);
        r.push_row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(0.0000005), "0.5us");
        assert_eq!(fmt_secs(0.0123), "12.30ms");
        assert_eq!(fmt_secs(1.5), "1.50s");
        assert_eq!(fmt_secs(300.0), "5.0m");
        assert_eq!(fmt_secs(7200.0), "2.0h");
        assert_eq!(fmt_secs(f64::INFINITY), "-");
    }
}
