//! Timing utilities for the experiment drivers: wall-clock measurement
//! with per-point timeouts (the paper's App. E protocol: the timeout is
//! checked after each test-point prediction, so it can be exceeded by
//! at most one prediction), plus summary statistics and a minimal
//! thread-pool `parallel_map` for the App. H comparison.

use std::time::{Duration, Instant};

/// Result of timing a prediction sweep.
#[derive(Clone, Debug)]
pub struct SweepTiming {
    /// seconds per completed prediction (empty if none completed)
    pub per_point_s: Vec<f64>,
    /// true when the timeout stopped the sweep early
    pub timed_out: bool,
}

impl SweepTiming {
    pub fn avg(&self) -> Option<f64> {
        if self.per_point_s.is_empty() {
            None
        } else {
            Some(self.per_point_s.iter().sum::<f64>() / self.per_point_s.len() as f64)
        }
    }

    pub fn completed(&self) -> usize {
        self.per_point_s.len()
    }
}

/// Time `f(i)` for i in 0..n_points, stopping once the cumulative time
/// exceeds `timeout` (checked after each point, like the paper).
pub fn time_sweep(
    n_points: usize,
    timeout: Duration,
    mut f: impl FnMut(usize),
) -> SweepTiming {
    let mut per_point = Vec::with_capacity(n_points);
    let start = Instant::now();
    let mut timed_out = false;
    for i in 0..n_points {
        let t0 = Instant::now();
        f(i);
        per_point.push(t0.elapsed().as_secs_f64());
        if start.elapsed() > timeout {
            timed_out = i + 1 < n_points;
            break;
        }
    }
    SweepTiming {
        per_point_s: per_point,
        timed_out,
    }
}

/// Time one closure.
pub fn time_once<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// mean and sample std
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    crate::cp::metrics::mean_std(xs)
}

/// Least-squares slope of log(y) vs log(x) — used by the Table 1
/// validation to compare measured growth exponents with the analytic
/// complexities.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    let pts: Vec<(f64, f64)> = xs
        .iter()
        .zip(ys)
        .filter(|(&x, &y)| x > 0.0 && y > 0.0)
        .map(|(&x, &y)| (x.ln(), y.ln()))
        .collect();
    let n = pts.len() as f64;
    if pts.len() < 2 {
        return f64::NAN;
    }
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

/// Minimal scoped parallel map over indices (App. H's multiprocessing
/// analogue): spawns `threads` workers that pull indices from a shared
/// counter. Results are returned in index order.
pub fn parallel_map<R: Send>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> R + Sync,
) -> Vec<R> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let counter = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<R>>> =
        Mutex::new((0..n).map(|_| None).collect());
    // THREADS: scoped workers joined at scope exit; the atomic counter
    // hands each index to exactly one worker.
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            scope.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                // LOCK-ORDER: bench.result_slots — innermost, one slot
                // store per acquisition; `f` runs outside the lock.
                out.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("worker filled every slot"))
        .collect()
}

/// Criterion-style microbenchmark (the offline environment has no
/// criterion crate): warm up, pick an iteration count targeting
/// ~`budget` of runtime, then report mean ± std per iteration.
pub fn microbench<R>(name: &str, budget: Duration, mut f: impl FnMut() -> R) -> f64 {
    // warmup + calibration
    let t0 = Instant::now();
    let mut warm_iters = 0u64;
    while t0.elapsed() < budget / 10 || warm_iters < 3 {
        std::hint::black_box(f());
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = t0.elapsed().as_secs_f64() / warm_iters as f64;
    let iters = ((budget.as_secs_f64() / per_iter) as u64).clamp(3, 1_000_000);
    // measure in 5 batches for a std estimate
    let batches = 5u64.min(iters);
    let per_batch = (iters / batches).max(1);
    let mut samples = Vec::with_capacity(batches as usize);
    for _ in 0..batches {
        let t = Instant::now();
        for _ in 0..per_batch {
            std::hint::black_box(f());
        }
        samples.push(t.elapsed().as_secs_f64() / per_batch as f64);
    }
    let (mean, std) = mean_std(&samples);
    println!(
        "{name:<44} {:>12}/iter (±{:>10}, {} iters)",
        crate::bench_harness::report::fmt_secs(mean),
        crate::bench_harness::report::fmt_secs(std),
        per_batch * batches,
    );
    mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_respects_timeout() {
        let t = time_sweep(1000, Duration::from_millis(20), |_| {
            std::thread::sleep(Duration::from_millis(8));
        });
        assert!(t.timed_out);
        assert!(t.completed() >= 2 && t.completed() < 10, "{}", t.completed());
        assert!(t.avg().unwrap() >= 0.007);
    }

    #[test]
    fn sweep_completes_within_budget() {
        let t = time_sweep(5, Duration::from_secs(10), |_| {});
        assert!(!t.timed_out);
        assert_eq!(t.completed(), 5);
    }

    #[test]
    fn loglog_slope_recovers_exponent() {
        let xs: Vec<f64> = (1..=8).map(|i| (10 * i) as f64).collect();
        let quad: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        assert!((loglog_slope(&xs, &quad) - 2.0).abs() < 1e-9);
        let lin: Vec<f64> = xs.iter().map(|x| 0.5 * x).collect();
        assert!((loglog_slope(&xs, &lin) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn parallel_map_ordered_and_complete() {
        let got = parallel_map(100, 4, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }
}
