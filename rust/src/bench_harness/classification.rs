//! Figure 2 / Figure 3 / Figure 6 / Table 1 drivers: prediction and
//! training time of standard vs optimized full CP vs ICP on the paper's
//! §7 workload (`make_classification`, binary, p = 30).
//!
//! The paper runs n up to 1e5 with a 10 h timeout on a Xeon; the default
//! grid here is scaled for a 1-core minutes-budget testbed (DESIGN.md
//! §4); `--paper-scale` restores the paper grid. What must reproduce is
//! the *shape*: standard CP grows ~1 power of n faster than optimized
//! CP, ICP is fastest, and optimized CP is within practical reach of ICP
//! — which the `table1` slope validation checks quantitatively.

use std::time::Duration;

use anyhow::Result;

use crate::bench_harness::report::{fmt_secs, Report};
use crate::bench_harness::timing::{loglog_slope, time_once, time_sweep};
use crate::config::{Config, MeasureKind};
use crate::coordinator::factory::{build_measure, build_standard_measure};
use crate::cp::icp::{Icp, IcpMeasure};
use crate::cp::measure::CpMeasure;
use crate::cp::pvalue::p_value;
use crate::data::{make_classification, ClassificationSpec, Dataset, Rng};
use crate::measures::{
    BootstrapParams, FeatureMap, IcpKde, IcpKnn, IcpLsSvm, IcpRandomForest,
};

/// Measure-variant axis of Figures 2/3/6.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    Standard,
    Optimized,
    Icp,
}

impl Variant {
    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Standard => "standard",
            Variant::Optimized => "optimized",
            Variant::Icp => "icp",
        }
    }
}

/// Default scaled log-grid (13 values over [10, 10^5] in the paper;
/// here over [10, ~4.6k] — same spacing, truncated).
pub fn default_grid(paper_scale: bool) -> Vec<usize> {
    let top = if paper_scale { 5.0 } else { 3.6666 };
    let k = if paper_scale { 13 } else { 9 };
    (0..k)
        .map(|i| {
            let e = 1.0 + (top - 1.0) * i as f64 / (k - 1) as f64;
            10f64.powf(e) as usize
        })
        .collect()
}

fn dataset(n: usize, seed: u64) -> Dataset {
    make_classification(
        &ClassificationSpec {
            n_samples: n,
            n_features: 30,
            ..Default::default()
        },
        seed,
    )
}

/// Build the ICP measure for a kind.
fn build_icp(kind: MeasureKind, cfg: &Config) -> Box<dyn IcpMeasure> {
    let m = &cfg.measure;
    match kind {
        MeasureKind::Knn => Box::new(IcpKnn::new(m.k, false)),
        MeasureKind::SimplifiedKnn => Box::new(IcpKnn::new(m.k, true)),
        MeasureKind::Kde => Box::new(IcpKde::new(m.h)),
        MeasureKind::LsSvm => Box::new(IcpLsSvm::new(m.rho, FeatureMap::Linear)),
        MeasureKind::RandomForest => Box::new(IcpRandomForest::new(
            BootstrapParams {
                b: m.b,
                ..Default::default()
            },
        )),
    }
}

/// One timed cell: returns (train_s, avg_predict_s, completed, timed_out).
pub fn run_cell(
    kind: MeasureKind,
    variant: Variant,
    ds: &Dataset,
    probe: &Dataset,
    cfg: &Config,
    timeout: Duration,
) -> (f64, Option<f64>, usize, bool) {
    // k must be compatible with class sizes on tiny n; the measures
    // handle underfull neighbourhoods, so no clamping is needed.
    match variant {
        Variant::Icp => {
            let t = ds.n() / 2;
            let measure = build_icp(kind, cfg);
            let (icp, train_s) =
                time_once(|| Icp::calibrate(BoxedIcp(measure), ds, t.max(1)));
            let sweep = time_sweep(probe.n(), timeout, |i| {
                let _ = icp.p_values(probe.row(i));
            });
            (train_s, sweep.avg(), sweep.completed(), sweep.timed_out)
        }
        Variant::Standard | Variant::Optimized => {
            let mut measure: Box<dyn CpMeasure> = if variant == Variant::Optimized
            {
                build_measure(kind, &cfg.measure, None)
            } else {
                build_standard_measure(kind, &cfg.measure)
            };
            let (_, train_s) = time_once(|| measure.fit(ds));
            let sweep = time_sweep(probe.n(), timeout, |i| {
                for y in 0..ds.n_labels {
                    let _ = p_value(&measure.scores(probe.row(i), y));
                }
            });
            (train_s, sweep.avg(), sweep.completed(), sweep.timed_out)
        }
    }
}

/// Adapter: Box<dyn IcpMeasure> itself implements IcpMeasure.
struct BoxedIcp(Box<dyn IcpMeasure>);
impl IcpMeasure for BoxedIcp {
    fn name(&self) -> String {
        self.0.name()
    }
    fn fit(&mut self, proper: &Dataset) {
        self.0.fit(proper)
    }
    fn score(&self, x: &[f64], y: usize) -> f64 {
        self.0.score(x, y)
    }
}

/// Which measures a figure covers.
fn figure_measures(id: &str) -> Vec<MeasureKind> {
    match id {
        // Figure 2 main panel: k-NN, KDE, LS-SVM, Random Forest
        "fig2" => vec![
            MeasureKind::Knn,
            MeasureKind::Kde,
            MeasureKind::LsSvm,
            MeasureKind::RandomForest,
        ],
        // Figure 6 (App. F): k-NN vs Simplified k-NN
        "fig6" => vec![MeasureKind::Knn, MeasureKind::SimplifiedKnn],
        // Figure 3: training time of the optimized measures
        "fig3" => vec![
            MeasureKind::Knn,
            MeasureKind::SimplifiedKnn,
            MeasureKind::Kde,
            MeasureKind::LsSvm,
            MeasureKind::RandomForest,
        ],
        _ => MeasureKind::all().to_vec(),
    }
}

/// The Figure 2 / 6 driver (prediction time) — also records training
/// time, which the Figure 3 driver reuses.
pub fn run_prediction_figure(id: &str, cfg: &Config) -> Result<Report> {
    let exp = &cfg.experiment;
    let sizes = if exp.train_sizes.is_empty() {
        default_grid(exp.paper_scale)
    } else {
        exp.train_sizes.clone()
    };
    let timeout = Duration::from_secs_f64(exp.timeout_s);
    let mut report = Report::new(
        id,
        "prediction time per test point: standard vs optimized full CP vs ICP",
        &[
            "measure", "variant", "n", "seed", "train_s", "avg_predict_s",
            "completed", "timed_out",
        ],
    );
    // Once a (measure, variant) times out at some n, skip larger n for
    // that series — the paper's curves stop at the timeout line too.
    let mut dead: std::collections::HashSet<(MeasureKind, Variant)> =
        std::collections::HashSet::new();
    for &n in &sizes {
        if n < 4 {
            continue;
        }
        for seed in 0..exp.seeds {
            let ds = dataset(n, 1000 + seed);
            let mut rng = Rng::seed_from(2000 + seed);
            let probe = {
                // exchangeable probe: fresh draw from the same generator
                let extra = dataset(exp.n_test.max(1), 3000 + seed);
                let _ = &mut rng;
                extra
            };
            for kind in figure_measures(id) {
                for variant in
                    [Variant::Standard, Variant::Optimized, Variant::Icp]
                {
                    if dead.contains(&(kind, variant)) {
                        continue;
                    }
                    let (train_s, avg, done, timed_out) =
                        run_cell(kind, variant, &ds, &probe, cfg, timeout);
                    report.push_row(vec![
                        kind.as_str().into(),
                        variant.as_str().into(),
                        n.to_string(),
                        seed.to_string(),
                        format!("{train_s:.6}"),
                        avg.map(|a| format!("{a:.6}")).unwrap_or_default(),
                        done.to_string(),
                        timed_out.to_string(),
                    ]);
                    if timed_out && seed + 1 == exp.seeds {
                        dead.insert((kind, variant));
                    }
                }
            }
        }
        println!("  [{}] finished n = {}", id, n);
    }
    report.note(
        "Paper reference (Fig. 2, n = 1e5): optimized k-NN 0.63 s/pred vs \
         ~2 h standard; optimized LS-SVM 0.21 s vs >24.5 h standard; ICP \
         fastest throughout. Shape target: optimized ~1 power of n below \
         standard, ICP flat-ish.",
    );
    Ok(report)
}

/// Figure 3: training time of the optimized measures.
pub fn run_training_figure(cfg: &Config) -> Result<Report> {
    let exp = &cfg.experiment;
    let sizes = if exp.train_sizes.is_empty() {
        default_grid(exp.paper_scale)
    } else {
        exp.train_sizes.clone()
    };
    let mut report = Report::new(
        "fig3",
        "training time of optimized full CP",
        &["measure", "n", "seed", "train_s"],
    );
    for &n in &sizes {
        if n < 4 {
            continue;
        }
        for seed in 0..exp.seeds {
            let ds = dataset(n, 1000 + seed);
            for kind in figure_measures("fig3") {
                let mut m = build_measure(kind, &cfg.measure, None);
                let (_, train_s) = time_once(|| m.fit(&ds));
                report.push_row(vec![
                    kind.as_str().into(),
                    n.to_string(),
                    seed.to_string(),
                    format!("{train_s:.6}"),
                ]);
            }
        }
        println!("  [fig3] finished n = {}", n);
    }
    report.note(
        "Paper reference (Fig. 3): LS-SVM highest training cost, Random \
         Forest lowest; k-NN/KDE quadratic in n.",
    );
    Ok(report)
}

/// Table 1 validation: fit log-log slopes on the fig2 data and compare
/// with the analytic complexity exponents.
pub fn run_table1(cfg: &Config) -> Result<Report> {
    // run a dedicated, smaller sweep for clean slopes
    let mut c = cfg.clone();
    if c.experiment.train_sizes.is_empty() {
        c.experiment.train_sizes = vec![32, 64, 128, 256, 512, 1024];
    }
    c.experiment.seeds = c.experiment.seeds.min(2);
    let fig2 = run_prediction_figure("table1-sweep", &c)?;

    // aggregate: avg predict per (measure, variant, n)
    let mut series: std::collections::BTreeMap<(String, String), Vec<(f64, f64)>> =
        Default::default();
    for row in &fig2.rows {
        let (m, v, n, avg) = (&row[0], &row[1], &row[2], &row[5]);
        if avg.is_empty() {
            continue;
        }
        series
            .entry((m.clone(), v.clone()))
            .or_default()
            .push((n.parse().unwrap(), avg.parse().unwrap()));
    }
    let analytic = |m: &str, v: &str| -> &'static str {
        match (m, v) {
            ("knn", "standard") | ("simplified-knn", "standard") => "2",
            ("knn", "optimized") | ("simplified-knn", "optimized") => "1",
            ("kde", "standard") => "2",
            ("kde", "optimized") => "1",
            ("lssvm", "standard") => "w+1 in [3,4]",
            ("lssvm", "optimized") => "1",
            ("rf", "standard") => "~2 (T_g(n)·n)",
            ("rf", "optimized") => "~1..2 (B' effect)",
            (_, "icp") => "<=1",
            _ => "?",
        }
    };
    let mut report = Report::new(
        "table1",
        "measured log-log growth of predict time vs analytic complexity (Table 1)",
        &["measure", "variant", "measured_slope", "analytic_exponent", "points"],
    );
    for ((m, v), mut pts) in series {
        pts.sort_by(|a, b| a.0.total_cmp(&b.0));
        // average duplicate-n entries (seeds)
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut i = 0;
        while i < pts.len() {
            let n = pts[i].0;
            let mut s = 0.0;
            let mut c = 0;
            while i < pts.len() && pts[i].0 == n {
                s += pts[i].1;
                c += 1;
                i += 1;
            }
            xs.push(n);
            ys.push(s / c as f64);
        }
        let slope = loglog_slope(&xs, &ys);
        report.push_row(vec![
            m.clone(),
            v.clone(),
            format!("{slope:.2}"),
            analytic(&m, &v).into(),
            xs.len().to_string(),
        ]);
    }
    report.note(
        "Slopes below ~0.3 indicate constant-dominated regimes at this \
         scale (small-n overheads); the standard-vs-optimized gap of ~1 \
         power of n is the Table 1 claim under test.",
    );
    Ok(report)
}

/// Quick summary rows for the console (used by the CLI).
pub fn summarize_latest(report: &Report) -> String {
    let mut out = String::new();
    let mut latest: std::collections::BTreeMap<(String, String), (f64, String)> =
        Default::default();
    for row in &report.rows {
        if row[5].is_empty() {
            continue;
        }
        let key = (row[0].clone(), row[1].clone());
        let n: f64 = row[2].parse().unwrap_or(0.0);
        let cur = latest.entry(key).or_insert((0.0, String::new()));
        if n >= cur.0 {
            *cur = (n, row[5].clone());
        }
    }
    for ((m, v), (n, t)) in latest {
        let secs: f64 = t.parse().unwrap_or(f64::NAN);
        out.push_str(&format!(
            "    {m:<16} {v:<10} n={n:<8} {}\n",
            fmt_secs(secs)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> Config {
        let mut c = Config::default();
        c.experiment.train_sizes = vec![16, 32];
        c.experiment.n_test = 2;
        c.experiment.seeds = 1;
        c.experiment.timeout_s = 5.0;
        c.measure.k = 3;
        c.measure.b = 3;
        c
    }

    #[test]
    fn grid_shapes() {
        let g = default_grid(false);
        assert_eq!(g.len(), 9);
        assert_eq!(g[0], 10);
        assert!(g[8] > 4000 && g[8] < 5000);
        let gp = default_grid(true);
        assert_eq!(gp.len(), 13);
        assert_eq!(*gp.last().unwrap(), 100_000);
    }

    #[test]
    fn fig2_smoke() {
        let cfg = tiny_cfg();
        let r = run_prediction_figure("fig2", &cfg).unwrap();
        // 2 sizes x 1 seed x 4 measures x 3 variants
        assert_eq!(r.rows.len(), 2 * 4 * 3);
        assert!(r.rows.iter().all(|row| !row[5].is_empty()));
    }

    #[test]
    fn fig3_smoke() {
        let cfg = tiny_cfg();
        let r = run_training_figure(&cfg).unwrap();
        assert_eq!(r.rows.len(), 2 * 5);
    }

    #[test]
    fn optimized_beats_standard_at_moderate_n() {
        let mut cfg = tiny_cfg();
        cfg.experiment.train_sizes = vec![256];
        cfg.experiment.n_test = 3;
        let ds = dataset(256, 9);
        let probe = dataset(3, 10);
        let (_, std_avg, _, _) = run_cell(
            MeasureKind::SimplifiedKnn,
            Variant::Standard,
            &ds,
            &probe,
            &cfg,
            Duration::from_secs(30),
        );
        let (_, opt_avg, _, _) = run_cell(
            MeasureKind::SimplifiedKnn,
            Variant::Optimized,
            &ds,
            &probe,
            &cfg,
            Duration::from_secs(30),
        );
        let (s, o) = (std_avg.unwrap(), opt_avg.unwrap());
        assert!(
            o < s,
            "optimized ({o:.6}s) should beat standard ({s:.6}s) at n=256"
        );
    }
}
