//! Minimal TOML-subset parser for the config system.
//!
//! The offline build environment ships no `toml`/`serde` crates, so the
//! config format is parsed in-tree. Supported subset (all the config
//! system needs): `[section]` and `[a.b]` headers, `key = value` with
//! string / integer / float / bool / flat-array values, `#` comments.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed TOML-lite value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Flat document: dotted-path key -> value (`section.key`).
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.get(path).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn u64_or(&self, path: &str, default: u64) -> u64 {
        self.get(path).and_then(Value::as_u64).unwrap_or(default)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_array(&self, path: &str) -> Vec<usize> {
        self.get(path)
            .and_then(Value::as_array)
            .map(|a| a.iter().filter_map(Value::as_usize).collect())
            .unwrap_or_default()
    }

    pub fn f64_array(&self, path: &str) -> Vec<f64> {
        self.get(path)
            .and_then(Value::as_array)
            .map(|a| a.iter().filter_map(Value::as_f64).collect())
            .unwrap_or_default()
    }

    /// Distinct first path segments directly under `prefix.` — e.g. for
    /// entries `serve.deployment.a.k` and `serve.deployment.b.kind`,
    /// `subsections("serve.deployment")` yields `["a", "b"]` (sorted).
    /// The config system uses this to enumerate `[serve.deployment.X]`
    /// blocks without a schema.
    pub fn subsections(&self, prefix: &str) -> Vec<String> {
        let dotted = format!("{prefix}.");
        let mut out: Vec<String> = Vec::new();
        for key in self.entries.keys() {
            if let Some(rest) = key.strip_prefix(&dotted) {
                let seg = rest.split('.').next().unwrap_or("");
                if !seg.is_empty() && out.last().map(String::as_str) != Some(seg)
                {
                    out.push(seg.to_string());
                }
            }
        }
        // BTreeMap iteration is sorted, so segments arrive grouped; the
        // last-seen dedup above is sufficient.
        out
    }
}

fn parse_value(raw: &str) -> Result<Value> {
    let raw = raw.trim();
    if raw.is_empty() {
        bail!("empty value");
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let end = stripped
            .find('"')
            .context("unterminated string")?;
        return Ok(Value::Str(stripped[..end].to_string()));
    }
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if raw.starts_with('[') {
        let inner = raw
            .strip_prefix('[')
            .and_then(|s| s.strip_suffix(']'))
            .context("unterminated array")?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {raw:?}")
}

/// Parse a TOML-lite document.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (lineno, line) in text.lines().enumerate() {
        // strip comments (naive: '#' not inside a string — our strings
        // never contain '#' in configs; documented limitation)
        let line = match line.find('#') {
            Some(i) if !line[..i].contains('"') || line[..i].matches('"').count() % 2 == 0 => {
                &line[..i]
            }
            _ => line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(h) = line.strip_prefix('[') {
            let h = h
                .strip_suffix(']')
                .with_context(|| format!("line {}: bad section", lineno + 1))?;
            section = h.trim().to_string();
            continue;
        }
        let eq = line
            .find('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim();
        let value = parse_value(&line[eq + 1..])
            .with_context(|| format!("line {}", lineno + 1))?;
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(path, value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
            # top comment
            use_pjrt = true
            name = "hello"
            [measure]
            k = 15          # trailing comment
            h = 1.5
            [experiment]
            train_sizes = [10, 100, 1000]
            "#,
        )
        .unwrap();
        assert_eq!(doc.bool_or("use_pjrt", false), true);
        assert_eq!(doc.str_or("name", ""), "hello");
        assert_eq!(doc.usize_or("measure.k", 0), 15);
        assert_eq!(doc.f64_or("measure.h", 0.0), 1.5);
        assert_eq!(doc.usize_array("experiment.train_sizes"), vec![10, 100, 1000]);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let doc = parse("").unwrap();
        assert_eq!(doc.usize_or("nope", 7), 7);
        assert_eq!(doc.str_or("nope", "d"), "d");
    }

    #[test]
    fn int_value_readable_as_f64() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.f64_or("x", 0.0), 3.0);
    }

    #[test]
    fn f64_array_mixes_ints_and_floats() {
        let doc = parse("eps = [0.05, 0.1, 1]").unwrap();
        assert_eq!(doc.f64_array("eps"), vec![0.05, 0.1, 1.0]);
        assert!(doc.f64_array("missing").is_empty());
    }

    #[test]
    fn subsections_enumerates_blocks() {
        let doc = parse(
            r#"
            [serve.deployment.zeta]
            kind = "ridge"
            rho = 0.5
            [serve.deployment.alpha]
            k = 3
            [serve]
            workers = 2
            "#,
        )
        .unwrap();
        assert_eq!(
            doc.subsections("serve.deployment"),
            vec!["alpha", "zeta"]
        );
        assert!(doc.subsections("serve.nope").is_empty());
        assert_eq!(doc.str_or("serve.deployment.zeta.kind", ""), "ridge");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("key = ???").is_err());
        assert!(parse("[unclosed").is_err());
    }
}
