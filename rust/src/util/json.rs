//! Minimal JSON encode/decode for the coordinator's wire protocol
//! (JSON-lines over TCP). In-tree because the offline environment ships
//! no serde_json. Supports the full JSON value model; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: array of f64.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_f64).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize (compact).
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let s = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected , or ] at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected , or }} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("x", Json::from_f64_slice(&[1.0, 2.5, -3.0])),
            ("label", Json::Num(1.0)),
            ("name", Json::Str("knn \"opt\"".into())),
            ("flag", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let text = v.encode();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_nested_whitespace() {
        let v = Json::parse(r#" { "a" : [ 1 , 2.5e1 , { "b" : null } ] } "#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(25.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("123 456").is_err());
    }

    #[test]
    fn escapes_survive() {
        let v = Json::Str("line1\nline2\t\"q\"".into());
        assert_eq!(Json::parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""A""#).unwrap(),
            Json::Str("A".into())
        );
    }
}
