//! In-tree utilities replacing unavailable third-party crates in the
//! offline build environment: a TOML-subset config parser and a JSON
//! codec for the coordinator wire protocol.

pub mod json;
pub mod toml_lite;

pub use json::Json;
