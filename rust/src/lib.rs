//! # exact-cp — Exact Optimization of Conformal Predictors
//!
//! Production-grade reproduction of *Exact Optimization of Conformal
//! Predictors via Incremental and Decremental Learning* (Cherubin,
//! Chatzikokolakis & Jaggi, ICML 2021), as a three-layer Rust + JAX +
//! Pallas system: Pallas kernels and JAX graphs are AOT-lowered to HLO at
//! build time (`make artifacts`), and this crate loads and executes them
//! through the PJRT C API on the serving hot path — Python never runs at
//! request time.
//!
//! ## Layout
//!
//! - [`data`] — dataset substrate: deterministic RNG, sklearn-equivalent
//!   `make_classification` / `make_regression` ports, MNIST-like
//!   generator.
//! - [`linalg`] — dense linear algebra and distance kernels (native
//!   fallback for the PJRT path) plus `select_k` (introselect, the
//!   `numpy.argpartition` the paper's implementation relies on).
//! - [`cp`] — the conformal prediction core: nonconformity traits,
//!   p-values, full CP (Algorithm 1), ICP (Algorithm 2), metrics.
//! - [`measures`] — every nonconformity measure the paper studies, in
//!   *standard* and *optimized* (incremental&decremental) variants:
//!   k-NN, Simplified k-NN (§3), KDE (§4), kernel LS-SVM (§5),
//!   bootstrap / Random Forest (§6) with its decision-tree substrate.
//! - [`regression`] — full CP regression (§8): the Papadopoulos et al.
//!   (2011) k-NN regressor, our incremental&decremental optimization of
//!   it, ridge (RRCM) full CP, and ICP regression baselines.
//! - [`online`] — the Vovk et al. (2003) exchangeability/IID test with
//!   incremental p-values and betting martingales (§9, App. C.5).
//! - [`cluster`] — conformal clustering and anomaly detection (§9).
//! - [`runtime`] — PJRT client wrapper: artifact registry, shape
//!   bucketing, padding/masking, executable cache.
//! - [`coordinator`] — L3 serving system: request router, dynamic
//!   batcher, online learn/unlearn state management, metrics.
//! - [`obs`] — serving observability: stage-level tracing (lock-free
//!   span ring, Chrome-trace dump), per-deployment metrics, online
//!   validity monitoring. Provably off the exact-value path
//!   (EXACTNESS.md).
//! - [`bench_harness`] — drivers regenerating every table and figure of
//!   the paper's evaluation (see DESIGN.md §4).

pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod cp;
pub mod data;
pub mod linalg;
pub mod measures;
pub mod obs;
pub mod online;
pub mod regression;
pub mod runtime;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
