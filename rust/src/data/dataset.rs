//! Dataset container: flat row-major feature matrix + labels/targets.
//!
//! Flat storage (one `Vec<f64>`, row-major) keeps the hot loops
//! allocation-free and cache-friendly, and marshals to PJRT literals
//! without copies of structure.

use crate::data::rng::Rng;

/// Classification label (0-based class index).
pub type Label = usize;

/// A classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major `n x p` feature matrix.
    pub x: Vec<f64>,
    /// `n` class labels in `0..n_labels`.
    pub y: Vec<Label>,
    /// Feature dimensionality.
    pub p: usize,
    /// Number of distinct labels.
    pub n_labels: usize,
}

impl Dataset {
    pub fn new(x: Vec<f64>, y: Vec<Label>, p: usize, n_labels: usize) -> Self {
        assert_eq!(x.len(), y.len() * p, "feature matrix shape mismatch");
        debug_assert!(y.iter().all(|&l| l < n_labels));
        Dataset { x, y, p, n_labels }
    }

    /// Number of examples.
    #[inline]
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// The `i`-th feature row.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.x[i * self.p..(i + 1) * self.p]
    }

    /// Count of examples per label.
    pub fn label_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.n_labels];
        for &l in &self.y {
            c[l] += 1;
        }
        c
    }

    /// Append one example (used by the online coordinator path).
    pub fn push(&mut self, x: &[f64], y: Label) {
        assert_eq!(x.len(), self.p);
        self.x.extend_from_slice(x);
        self.y.push(y);
        if y >= self.n_labels {
            self.n_labels = y + 1;
        }
    }

    /// Remove the `i`-th example (swap-remove semantics are NOT used:
    /// order is preserved because optimized-measure state is indexed).
    pub fn remove(&mut self, i: usize) -> (Vec<f64>, Label) {
        let row = self.row(i).to_vec();
        let label = self.y.remove(i);
        self.x.drain(i * self.p..(i + 1) * self.p);
        (row, label)
    }

    /// Shuffled train/test split with `n_train` training examples.
    pub fn split(&self, n_train: usize, rng: &mut Rng) -> (Dataset, Dataset) {
        let n = self.n();
        assert!(n_train <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let take = |ids: &[usize]| {
            let mut x = Vec::with_capacity(ids.len() * self.p);
            let mut y = Vec::with_capacity(ids.len());
            for &i in ids {
                x.extend_from_slice(self.row(i));
                y.push(self.y[i]);
            }
            Dataset::new(x, y, self.p, self.n_labels)
        };
        (take(&idx[..n_train]), take(&idx[n_train..]))
    }

    /// First-`t` / rest split (ICP's proper-training / calibration split;
    /// the caller shuffles first if needed).
    pub fn split_at(&self, t: usize) -> (Dataset, Dataset) {
        let idx: Vec<usize> = (0..self.n()).collect();
        let take = |ids: &[usize]| {
            let mut x = Vec::with_capacity(ids.len() * self.p);
            let mut y = Vec::with_capacity(ids.len());
            for &i in ids {
                x.extend_from_slice(self.row(i));
                y.push(self.y[i]);
            }
            Dataset::new(x, y, self.p, self.n_labels)
        };
        (take(&idx[..t]), take(&idx[t..]))
    }

    /// Subset by indices (bootstrap samples).
    pub fn subset(&self, ids: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(ids.len() * self.p);
        let mut y = Vec::with_capacity(ids.len());
        for &i in ids {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset::new(x, y, self.p, self.n_labels)
    }
}

/// A regression dataset: features + real-valued targets.
#[derive(Clone, Debug)]
pub struct RegressionDataset {
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub p: usize,
}

impl RegressionDataset {
    pub fn new(x: Vec<f64>, y: Vec<f64>, p: usize) -> Self {
        assert_eq!(x.len(), y.len() * p);
        RegressionDataset { x, y, p }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.y.len()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.x[i * self.p..(i + 1) * self.p]
    }

    /// Append one example (the online learn path).
    pub fn push(&mut self, x: &[f64], y: f64) {
        assert_eq!(x.len(), self.p);
        self.x.extend_from_slice(x);
        self.y.push(y);
    }

    /// Remove the `i`-th example (swap-remove semantics are NOT used:
    /// order is preserved because decremental-regressor state — journal
    /// prefixes, neighbour statistics — is indexed in insertion order).
    pub fn remove(&mut self, i: usize) -> (Vec<f64>, f64) {
        let row = self.row(i).to_vec();
        let y = self.y.remove(i);
        self.x.drain(i * self.p..(i + 1) * self.p);
        (row, y)
    }

    pub fn split(
        &self,
        n_train: usize,
        rng: &mut Rng,
    ) -> (RegressionDataset, RegressionDataset) {
        let n = self.n();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let take = |ids: &[usize]| {
            let mut x = Vec::with_capacity(ids.len() * self.p);
            let mut y = Vec::with_capacity(ids.len());
            for &i in ids {
                x.extend_from_slice(self.row(i));
                y.push(self.y[i]);
            }
            RegressionDataset::new(x, y, self.p)
        };
        (take(&idx[..n_train]), take(&idx[n_train..]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(
            vec![0., 0., 1., 1., 2., 2., 3., 3.],
            vec![0, 1, 0, 1],
            2,
            2,
        )
    }

    #[test]
    fn rows_and_counts() {
        let d = toy();
        assert_eq!(d.n(), 4);
        assert_eq!(d.row(2), &[2., 2.]);
        assert_eq!(d.label_counts(), vec![2, 2]);
    }

    #[test]
    fn push_remove_roundtrip() {
        let mut d = toy();
        d.push(&[9., 9.], 1);
        assert_eq!(d.n(), 5);
        let (row, lab) = d.remove(4);
        assert_eq!(row, vec![9., 9.]);
        assert_eq!(lab, 1);
        assert_eq!(d.n(), 4);
        assert_eq!(d.row(3), &[3., 3.]);
    }

    #[test]
    fn remove_middle_preserves_order() {
        let mut d = toy();
        d.remove(1);
        assert_eq!(d.row(1), &[2., 2.]);
        assert_eq!(d.y, vec![0, 0, 1]);
    }

    #[test]
    fn split_partitions() {
        let d = toy();
        let mut rng = Rng::seed_from(3);
        let (tr, te) = d.split(3, &mut rng);
        assert_eq!(tr.n(), 3);
        assert_eq!(te.n(), 1);
        assert_eq!(tr.p, 2);
    }

    #[test]
    fn regression_push_remove_preserves_order() {
        let mut d = RegressionDataset::new(
            vec![0., 0., 1., 1., 2., 2.],
            vec![10., 11., 12.],
            2,
        );
        d.push(&[3., 3.], 13.);
        assert_eq!(d.n(), 4);
        let (row, y) = d.remove(1);
        assert_eq!(row, vec![1., 1.]);
        assert_eq!(y, 11.);
        assert_eq!(d.n(), 3);
        assert_eq!(d.row(1), &[2., 2.]);
        assert_eq!(d.y, vec![10., 12., 13.]);
    }
}
