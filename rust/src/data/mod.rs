//! Data substrate: deterministic RNG, dataset containers, and Rust ports
//! of the paper's workload generators (sklearn `make_classification` /
//! `make_regression`, MNIST-like).

pub mod dataset;
pub mod rng;
pub mod synth;

pub use dataset::{Dataset, Label, RegressionDataset};
pub use rng::Rng;
pub use synth::{
    make_classification, make_regression, mnist_like, ClassificationSpec,
    RegressionSpec,
};
