//! Deterministic RNG substrate: xoshiro256++ with SplitMix64 seeding.
//!
//! Every experiment in the paper is repeated over explicit seeds
//! (App. E); all randomness in this crate flows through this generator
//! so runs are bit-reproducible across machines, which the exactness
//! test-suite depends on. No external `rand` dependency: the generator
//! is ~40 lines and we control its stability.

/// xoshiro256++ PRNG (Blackman & Vigna). Passes BigCrush; 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from the Box-Muller pair
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single u64 via SplitMix64 (the
    /// canonical xoshiro seeding procedure).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for per-worker / per-repeat rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire rejection).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n {
                return (m >> 64) as usize;
            }
            // slow path: rejection to remove bias
            let t = n.wrapping_neg() % n;
            if l >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from 0..n (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::seed_from(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::seed_from(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from(17);
        let idx = r.sample_indices(50, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }
}
