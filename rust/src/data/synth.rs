//! Workload generators — Rust ports of the paper's data sources.
//!
//! The paper's experiments use scikit-learn's `make_classification()`
//! (§7: binary, 30 features) and `make_regression()` (§8), plus MNIST
//! (App. G). This module reimplements the sklearn constructions and a
//! deterministic MNIST-like generator (DESIGN.md §5 documents the MNIST
//! substitution: timing depends on (n, p, l) and fuzziness ordering on
//! separability, both of which the generator preserves).

use crate::data::dataset::{Dataset, RegressionDataset};
use crate::data::rng::Rng;

/// Parameters for [`make_classification`]; defaults match sklearn's.
#[derive(Clone, Debug)]
pub struct ClassificationSpec {
    pub n_samples: usize,
    pub n_features: usize,
    pub n_informative: usize,
    pub n_redundant: usize,
    pub n_classes: usize,
    pub class_sep: f64,
    /// fraction of labels randomly flipped (sklearn `flip_y`)
    pub flip_y: f64,
}

impl Default for ClassificationSpec {
    fn default() -> Self {
        // sklearn defaults, with n_features=30 as in the paper's §7 setup
        ClassificationSpec {
            n_samples: 100,
            n_features: 30,
            n_informative: 2,
            n_redundant: 2,
            n_classes: 2,
            class_sep: 1.0,
            flip_y: 0.01,
        }
    }
}

/// Port of sklearn's `make_classification`: class centroids on the
/// vertices of an `n_informative`-dim hypercube (scaled by `class_sep`),
/// Gaussian clusters around them, redundant features as random linear
/// combinations of informative ones, remaining features pure noise,
/// then global feature shuffle.
pub fn make_classification(spec: &ClassificationSpec, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from(seed);
    let ClassificationSpec {
        n_samples,
        n_features,
        n_informative,
        n_redundant,
        n_classes,
        class_sep,
        flip_y,
    } = *spec;
    assert!(n_informative + n_redundant <= n_features);
    assert!(n_classes >= 2);

    // Hypercube vertex centroids: the binary expansion of the class id,
    // mapped to {-class_sep, +class_sep}^n_informative.
    let centroid = |c: usize, j: usize| -> f64 {
        if (c >> (j % 63)) & 1 == 1 {
            class_sep
        } else {
            -class_sep
        }
    };

    // Redundant-feature mixing matrix: n_informative x n_redundant.
    let mix: Vec<f64> = (0..n_informative * n_redundant)
        .map(|_| 2.0 * rng.f64() - 1.0)
        .collect();

    // Column shuffle so informative features are not positionally fixed.
    let mut cols: Vec<usize> = (0..n_features).collect();
    rng.shuffle(&mut cols);

    let mut x = vec![0.0; n_samples * n_features];
    let mut y = Vec::with_capacity(n_samples);
    let mut info = vec![0.0; n_informative];
    for i in 0..n_samples {
        let c = i % n_classes; // balanced classes
        for (j, v) in info.iter_mut().enumerate() {
            *v = centroid(c, j) + rng.normal();
        }
        let row = &mut x[i * n_features..(i + 1) * n_features];
        for j in 0..n_features {
            let src = cols[j];
            row[j] = if src < n_informative {
                info[src]
            } else if src < n_informative + n_redundant {
                let r = src - n_informative;
                (0..n_informative)
                    .map(|k| info[k] * mix[k * n_redundant + r])
                    .sum()
            } else {
                rng.normal()
            };
        }
        let label = if flip_y > 0.0 && rng.f64() < flip_y {
            rng.below(n_classes)
        } else {
            c
        };
        y.push(label);
    }
    let mut ds = Dataset::new(x, y, n_features, n_classes);
    // Row shuffle so class order is not systematic.
    shuffle_rows(&mut ds, &mut rng);
    ds
}

fn shuffle_rows(ds: &mut Dataset, rng: &mut Rng) {
    let n = ds.n();
    let p = ds.p;
    for i in (1..n).rev() {
        let j = rng.below(i + 1);
        if i != j {
            ds.y.swap(i, j);
            for k in 0..p {
                ds.x.swap(i * p + k, j * p + k);
            }
        }
    }
}

/// Parameters for [`make_regression`]; defaults match sklearn's with the
/// paper's p=30.
#[derive(Clone, Debug)]
pub struct RegressionSpec {
    pub n_samples: usize,
    pub n_features: usize,
    pub n_informative: usize,
    pub noise: f64,
}

impl Default for RegressionSpec {
    fn default() -> Self {
        RegressionSpec {
            n_samples: 100,
            n_features: 30,
            n_informative: 10,
            noise: 0.0,
        }
    }
}

/// Port of sklearn's `make_regression`: standard-normal X, targets a
/// random sparse linear model (coefficients ~ 100 * U[0,1] on the
/// informative subspace) plus optional Gaussian noise.
pub fn make_regression(spec: &RegressionSpec, seed: u64) -> RegressionDataset {
    let mut rng = Rng::seed_from(seed);
    let RegressionSpec {
        n_samples,
        n_features,
        n_informative,
        noise,
    } = *spec;
    let coef: Vec<f64> = (0..n_informative).map(|_| 100.0 * rng.f64()).collect();
    let mut x = vec![0.0; n_samples * n_features];
    for v in x.iter_mut() {
        *v = rng.normal();
    }
    let mut y = Vec::with_capacity(n_samples);
    for i in 0..n_samples {
        let row = &x[i * n_features..(i + 1) * n_features];
        let mut t: f64 = (0..n_informative).map(|j| row[j] * coef[j]).sum();
        if noise > 0.0 {
            t += noise * rng.normal();
        }
        y.push(t);
    }
    RegressionDataset::new(x, y, n_features)
}

/// Deterministic MNIST-like generator (App. G substitution, DESIGN.md §5):
/// 10 balanced classes over 784 "pixel" features in [0, 1]. Each class is
/// a smooth random prototype plus a random `manifold_dim`-dimensional
/// linear manifold plus pixel noise, clipped to [0, 1] — matching MNIST's
/// shape (n x 784, 10 labels), bounded range, and per-class low intrinsic
/// dimensionality, which is what drives both the timing results and the
/// fuzziness comparison.
pub fn mnist_like(n_samples: usize, seed: u64) -> Dataset {
    const P: usize = 784;
    const CLASSES: usize = 10;
    const MANIFOLD: usize = 8;
    let mut rng = Rng::seed_from(seed);

    // Smooth prototypes: random low-frequency blobs on the 28x28 grid.
    let mut protos = vec![0.0; CLASSES * P];
    for c in 0..CLASSES {
        // 4 Gaussian blobs per class prototype
        for _ in 0..4 {
            let (cx, cy) = (4.0 + 20.0 * rng.f64(), 4.0 + 20.0 * rng.f64());
            let s = 2.0 + 3.0 * rng.f64();
            let amp = 0.5 + 0.5 * rng.f64();
            for yy in 0..28 {
                for xx in 0..28 {
                    let d2 = (xx as f64 - cx).powi(2) + (yy as f64 - cy).powi(2);
                    protos[c * P + yy * 28 + xx] += amp * (-d2 / (2.0 * s * s)).exp();
                }
            }
        }
    }
    // Per-class manifold directions. Scaled so classes overlap for a
    // minority of samples — real MNIST has ~3% 1-NN error; a generator
    // with zero overlap degenerates the App. G fuzziness comparison
    // (every wrong label would sit exactly at the 1/(n+1) floor).
    let mut dirs = vec![0.0; CLASSES * MANIFOLD * P];
    for v in dirs.iter_mut() {
        *v = rng.normal() * 0.12;
    }

    let mut x = vec![0.0; n_samples * P];
    let mut y = Vec::with_capacity(n_samples);
    for i in 0..n_samples {
        let c = i % CLASSES;
        let row = &mut x[i * P..(i + 1) * P];
        row.copy_from_slice(&protos[c * P..(c + 1) * P]);
        for m in 0..MANIFOLD {
            let z = rng.normal();
            let d = &dirs[(c * MANIFOLD + m) * P..(c * MANIFOLD + m + 1) * P];
            for (r, dv) in row.iter_mut().zip(d) {
                *r += z * dv;
            }
        }
        for r in row.iter_mut() {
            *r = (*r + 0.08 * rng.normal()).clamp(0.0, 1.0);
        }
        y.push(c);
    }
    let mut ds = Dataset::new(x, y, P, CLASSES);
    shuffle_rows(&mut ds, &mut rng);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_shapes_and_balance() {
        let ds = make_classification(
            &ClassificationSpec {
                n_samples: 200,
                ..Default::default()
            },
            1,
        );
        assert_eq!(ds.n(), 200);
        assert_eq!(ds.p, 30);
        let counts = ds.label_counts();
        assert_eq!(counts.len(), 2);
        // balanced up to flip_y noise
        assert!((counts[0] as i64 - 100).abs() < 15, "{counts:?}");
    }

    #[test]
    fn classification_is_separable_enough() {
        // 1-NN on a held-out split should beat chance comfortably: the
        // informative subspace must actually carry signal.
        let ds = make_classification(
            &ClassificationSpec {
                n_samples: 400,
                class_sep: 2.0,
                flip_y: 0.0,
                ..Default::default()
            },
            2,
        );
        let mut rng = Rng::seed_from(3);
        let (tr, te) = ds.split(300, &mut rng);
        let mut correct = 0;
        for i in 0..te.n() {
            let q = te.row(i);
            let mut best = (f64::INFINITY, 0usize);
            for j in 0..tr.n() {
                let d: f64 = q
                    .iter()
                    .zip(tr.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < best.0 {
                    best = (d, tr.y[j]);
                }
            }
            if best.1 == te.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.n() as f64;
        assert!(acc > 0.7, "1-NN accuracy too low: {acc}");
    }

    #[test]
    fn classification_deterministic() {
        let a = make_classification(&Default::default(), 7);
        let b = make_classification(&Default::default(), 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn regression_is_linear_signal() {
        let ds = make_regression(
            &RegressionSpec {
                n_samples: 300,
                noise: 0.0,
                ..Default::default()
            },
            5,
        );
        assert_eq!(ds.n(), 300);
        // Exact linear model: y variance should be fully explained by X's
        // informative block; sanity-check magnitudes.
        let var: f64 =
            ds.y.iter().map(|v| v * v).sum::<f64>() / ds.n() as f64;
        assert!(var > 1.0, "targets look degenerate: var={var}");
    }

    #[test]
    fn mnist_like_shape_range_classes() {
        let ds = mnist_like(100, 9);
        assert_eq!(ds.p, 784);
        assert_eq!(ds.n_labels, 10);
        assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let counts = ds.label_counts();
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }
}
