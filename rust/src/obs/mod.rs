//! Serving observability: stage-level tracing, per-deployment metrics
//! and online validity monitoring.
//!
//! Everything in this module is *off the exact-value path* by
//! construction: instrumentation reads the wall clock and finished
//! outputs (p-values, interval endpoints) and never participates in
//! float compute. `obs/` is deliberately NOT in the EXACT-critical
//! module list (see EXACTNESS.md and `xtask::exactness`); its one lock
//! (`obs.deployments`) is the lowest-ranked row of the lock-order
//! table, so it can be taken while holding any serving lock without
//! deadlock risk.
//!
//! - [`trace`]: span timers over a lock-free seqlock ring, Chrome-trace
//!   dump (`op:"trace"`) and a background JSONL writer (`--trace-out`).
//! - [`hist`]: fixed-bucket atomic histograms (the storage primitive).
//! - [`metrics`]: per-deployment × per-op metric blocks.
//! - [`validity`]: online empirical error rate vs. tracked epsilons,
//!   set-size / interval-width histograms, p-value uniformity.

pub mod hist;
pub mod metrics;
pub mod trace;
pub mod validity;

pub use hist::AtomicHist;
pub use metrics::{DeploymentObs, ObsRegistry, OpKind, OpMetrics};
pub use trace::{
    chrome_trace_json, span, span_args, Stage, TraceEvent, TraceRing,
};
pub use validity::ValidityMonitor;
