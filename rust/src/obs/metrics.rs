//! Per-deployment × per-op metric blocks.
//!
//! The coordinator keeps one global [`crate::coordinator::metrics::Metrics`]
//! for process-wide counters; this registry splits the interesting ones
//! (request counts, latency histograms, batch sizes, validity) by
//! deployment and wire op, so `op:"stats"` can answer "where does
//! deployment X's p99 come from" instead of one blended number.
//!
//! Blocks are created lazily on first touch and live for the process:
//! the registry RwLock (`obs.deployments` in the lock-rank table) is
//! held only for the HashMap probe — every metric update happens on an
//! `Arc`'d block after the guard drops.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::obs::hist::AtomicHist;
use crate::obs::validity::ValidityMonitor;
use crate::util::json::Json;

/// Wire ops that get their own metric block per deployment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Predict = 0,
    PredictRegion = 1,
    Learn = 2,
    Unlearn = 3,
}

impl OpKind {
    pub const ALL: [OpKind; 4] = [
        OpKind::Predict,
        OpKind::PredictRegion,
        OpKind::Learn,
        OpKind::Unlearn,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Predict => "predict",
            OpKind::PredictRegion => "predict_region",
            OpKind::Learn => "learn",
            OpKind::Unlearn => "unlearn",
        }
    }

    pub fn from_op(op: &str) -> Option<OpKind> {
        match op {
            "predict" => Some(OpKind::Predict),
            "predict_region" => Some(OpKind::PredictRegion),
            "learn" => Some(OpKind::Learn),
            "unlearn" => Some(OpKind::Unlearn),
            _ => None,
        }
    }
}

/// Counters + latency histogram for one (deployment, op) pair. Every
/// response arm feeds the histogram — success, error AND rejected — so
/// tail quantiles are not survivorship-biased under backpressure.
pub struct OpMetrics {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub rejected: AtomicU64,
    pub latency: AtomicHist,
}

impl OpMetrics {
    fn new() -> OpMetrics {
        OpMetrics {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            latency: AtomicHist::latency_us(),
        }
    }

    /// Successful response after `us` microseconds.
    pub fn record_ok(&self, us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latency.observe(us as f64);
    }

    /// Error response after `us` microseconds.
    pub fn record_error(&self, us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
        self.latency.observe(us as f64);
    }

    /// Backpressure rejection after `us` microseconds.
    pub fn record_rejected(&self, us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rejected.fetch_add(1, Ordering::Relaxed);
        self.latency.observe(us as f64);
    }

    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            (
                "requests",
                Json::Num(self.requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "errors",
                Json::Num(self.errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "rejected",
                Json::Num(self.rejected.load(Ordering::Relaxed) as f64),
            ),
            ("latency_us", self.latency.snapshot()),
        ])
    }
}

/// All observability state for one deployment.
pub struct DeploymentObs {
    ops: [OpMetrics; 4],
    /// Size of each scored sub-batch routed to this deployment.
    pub batch_sizes: AtomicHist,
    pub validity: ValidityMonitor,
}

impl DeploymentObs {
    fn new(epsilons: &[f64]) -> DeploymentObs {
        DeploymentObs {
            ops: [
                OpMetrics::new(),
                OpMetrics::new(),
                OpMetrics::new(),
                OpMetrics::new(),
            ],
            batch_sizes: AtomicHist::linear(64),
            validity: ValidityMonitor::new(epsilons),
        }
    }

    pub fn op(&self, kind: OpKind) -> &OpMetrics {
        &self.ops[kind as usize]
    }

    pub fn record_batch(&self, size: usize) {
        self.batch_sizes.observe(size as f64);
    }

    pub fn snapshot(&self) -> Json {
        let ops = OpKind::ALL
            .iter()
            .map(|&k| (k.name(), self.op(k).snapshot()))
            .collect();
        Json::obj(vec![
            ("ops", Json::obj(ops)),
            ("batch_size", self.batch_sizes.snapshot()),
            ("validity", self.validity.snapshot()),
        ])
    }
}

/// Registry of per-deployment metric blocks, keyed by deployment name.
pub struct ObsRegistry {
    epsilons: Vec<f64>,
    deployments: RwLock<HashMap<String, Arc<DeploymentObs>>>,
}

impl ObsRegistry {
    pub fn new(epsilons: Vec<f64>) -> ObsRegistry {
        ObsRegistry {
            epsilons,
            deployments: RwLock::new(HashMap::new()),
        }
    }

    /// Epsilons every deployment's validity monitor tracks.
    pub fn epsilons(&self) -> &[f64] {
        &self.epsilons
    }

    /// The metric block for `name`, created on first touch. The guard
    /// is dropped before returning: callers update the block lock-free.
    pub fn get(&self, name: &str) -> Arc<DeploymentObs> {
        {
            // LOCK-ORDER: obs.deployments — lowest-ranked leaf lock,
            // held only for the HashMap probe; no other lock is taken
            // while held.
            let map = self.deployments.read().unwrap();
            if let Some(d) = map.get(name) {
                return d.clone();
            }
        }
        // LOCK-ORDER: obs.deployments — write to insert a fresh block;
        // entry() re-checks so racing creators converge on one Arc.
        let mut map = self.deployments.write().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| Arc::new(DeploymentObs::new(&self.epsilons)))
            .clone()
    }

    /// The block for `name` if it exists (no creation).
    pub fn peek(&self, name: &str) -> Option<Arc<DeploymentObs>> {
        // LOCK-ORDER: obs.deployments — read-only probe, leaf lock.
        self.deployments.read().unwrap().get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        // LOCK-ORDER: obs.deployments — read-only key listing, leaf
        // lock.
        let mut out: Vec<String> =
            self.deployments.read().unwrap().keys().cloned().collect();
        out.sort();
        out
    }

    /// `{deployment: snapshot}` for every known deployment.
    pub fn snapshot(&self) -> Json {
        let snap: Vec<(String, Arc<DeploymentObs>)> = {
            // LOCK-ORDER: obs.deployments — clone the Arc table, then
            // snapshot outside the guard (snapshots only read atomics).
            let map = self.deployments.read().unwrap();
            map.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        Json::Obj(
            snap.into_iter()
                .map(|(k, v)| (k, v.snapshot()))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_kind_round_trip() {
        for k in OpKind::ALL {
            assert_eq!(OpKind::from_op(k.name()), Some(k));
        }
        assert_eq!(OpKind::from_op("stats"), None);
    }

    #[test]
    fn registry_creates_once_and_lists_sorted() {
        let reg = ObsRegistry::new(vec![0.1]);
        let a1 = reg.get("zeta");
        let a2 = reg.get("zeta");
        assert!(Arc::ptr_eq(&a1, &a2));
        reg.get("alpha");
        assert_eq!(reg.names(), vec!["alpha", "zeta"]);
        assert!(reg.peek("missing").is_none());
        assert!(reg.peek("alpha").is_some());
    }

    #[test]
    fn all_response_arms_feed_latency() {
        let reg = ObsRegistry::new(vec![0.1]);
        let d = reg.get("m");
        let op = d.op(OpKind::Predict);
        op.record_ok(100);
        op.record_error(200);
        op.record_rejected(300);
        assert_eq!(op.requests.load(Ordering::Relaxed), 3);
        assert_eq!(op.errors.load(Ordering::Relaxed), 1);
        assert_eq!(op.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(op.latency.count(), 3, "rejected+error arms in hist");
    }

    #[test]
    fn snapshot_shape_is_stable() {
        let reg = ObsRegistry::new(vec![0.05, 0.1]);
        let d = reg.get("m");
        d.op(OpKind::Predict).record_ok(50);
        d.record_batch(4);
        let s = reg.snapshot();
        let m = s.get("m").expect("deployment key");
        for key in ["ops", "batch_size", "validity"] {
            assert!(m.get(key).is_some(), "missing {key}");
        }
        let ops = m.get("ops").unwrap();
        for op in ["predict", "predict_region", "learn", "unlearn"] {
            let block = ops.get(op).expect(op);
            for key in ["requests", "errors", "rejected", "latency_us"] {
                assert!(block.get(key).is_some(), "missing {op}.{key}");
            }
        }
    }
}
