//! Fixed-bucket atomic histograms — the storage primitive behind every
//! observability metric (latency, batch size, set size, interval width,
//! p-value uniformity).
//!
//! All updates are relaxed atomics on preallocated buckets: `observe` is
//! wait-free and never allocates, so it is safe to call from the serving
//! hot path. The running `sum` is kept as an `f64` bit pattern updated
//! by CAS — this is a *monitoring* aggregate, never compared bitwise
//! against anything, so the nondeterministic accumulation order under
//! concurrency is acceptable (and `obs/` is deliberately outside the
//! EXACT-critical module list; see EXACTNESS.md).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// A fixed-bucket histogram with atomic counters.
///
/// `bounds[i]` is the inclusive upper bound of bucket `i`; the last
/// bound should be `f64::INFINITY` so every value (including
/// `u64::MAX as f64`) lands somewhere — `observe` clamps to the last
/// bucket regardless, so a histogram without an infinite tail still
/// never drops a sample.
pub struct AtomicHist {
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
    /// total observation count (== sum of bucket counts)
    n: AtomicU64,
    /// running sum of observed values, stored as f64 bits
    sum_bits: AtomicU64,
}

/// CAS-add a value into an f64 stored as bits in an `AtomicU64`.
fn add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(
            cur,
            new,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

impl AtomicHist {
    /// Build from explicit bucket upper bounds (ascending).
    pub fn new(bounds: Vec<f64>) -> Self {
        assert!(!bounds.is_empty());
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "bounds must be strictly ascending"
        );
        let counts = bounds.iter().map(|_| AtomicU64::new(0)).collect();
        AtomicHist {
            bounds,
            counts,
            n: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// Log-spaced microsecond latency buckets (the coordinator default),
    /// with an infinite overflow tail.
    pub fn latency_us() -> Self {
        Self::new(vec![
            50.0,
            100.0,
            250.0,
            500.0,
            1_000.0,
            2_500.0,
            5_000.0,
            10_000.0,
            25_000.0,
            100_000.0,
            1_000_000.0,
            f64::INFINITY,
        ])
    }

    /// Linear integer buckets `1..=max` plus an overflow tail — batch
    /// sizes, prediction-set sizes, queue depths.
    pub fn linear(max: usize) -> Self {
        let mut bounds: Vec<f64> = (0..=max).map(|i| i as f64).collect();
        bounds.push(f64::INFINITY);
        Self::new(bounds)
    }

    /// `k` uniform buckets over `[0, 1]` — p-value uniformity tracking.
    pub fn unit_interval(k: usize) -> Self {
        assert!(k >= 1);
        let bounds = (1..=k).map(|i| i as f64 / k as f64).collect();
        Self::new(bounds)
    }

    /// Log-spaced width buckets for regression interval widths.
    pub fn widths() -> Self {
        Self::new(vec![
            0.01,
            0.1,
            0.5,
            1.0,
            2.0,
            5.0,
            10.0,
            50.0,
            100.0,
            1_000.0,
            f64::INFINITY,
        ])
    }

    /// Record one observation (wait-free, allocation-free).
    pub fn observe(&self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len() - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
        add_f64(&self.sum_bits, v);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    /// Sum of observed values.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// Approximate quantile: the upper bound of the bucket containing
    /// the q-th sample. Returns 0 for an empty histogram. An infinite
    /// tail bucket reports the last *finite* bound (the histogram's
    /// resolution limit) rather than `inf`, so JSON snapshots stay
    /// numeric.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self
            .counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().copied().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.finite_bound(i);
            }
        }
        self.finite_bound(self.bounds.len() - 1)
    }

    /// Bound of bucket `i`, substituting the largest finite bound for an
    /// infinite tail.
    fn finite_bound(&self, i: usize) -> f64 {
        let b = self.bounds[i];
        if b.is_finite() {
            b
        } else if i > 0 {
            self.bounds[i - 1]
        } else {
            0.0
        }
    }

    /// Per-bucket counts (for snapshots and tests).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Bucket upper bounds.
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// JSON snapshot with stable keys: `count`, `mean`, `p50`, `p99`,
    /// `bounds`, `counts` (infinite bounds serialize as JSON null).
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean", Json::Num(self.mean())),
            ("p50", Json::Num(self.quantile(0.5))),
            ("p99", Json::Num(self.quantile(0.99))),
            ("bounds", Json::from_f64_slice(&self.bounds)),
            (
                "counts",
                Json::Arr(
                    self.bucket_counts()
                        .into_iter()
                        .map(|c| Json::Num(c as f64))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn empty_histogram_quantiles_are_zero() {
        let h = AtomicHist::latency_us();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn overflow_bucket_catches_u64_max() {
        let h = AtomicHist::latency_us();
        h.observe(u64::MAX as f64);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 2);
        let counts = h.bucket_counts();
        assert_eq!(*counts.last().unwrap(), 2, "tail bucket holds both");
        // quantile reports the largest finite bound, not inf
        assert_eq!(h.quantile(0.99), 1_000_000.0);
    }

    #[test]
    fn no_infinite_tail_still_never_drops() {
        let h = AtomicHist::new(vec![1.0, 2.0]);
        h.observe(100.0);
        assert_eq!(h.bucket_counts(), vec![0, 1]);
        assert_eq!(h.quantile(0.5), 2.0);
    }

    #[test]
    fn quantiles_match_reference() {
        let h = AtomicHist::latency_us();
        for _ in 0..90 {
            h.observe(80.0); // bucket <= 100
        }
        for _ in 0..10 {
            h.observe(400_000.0); // bucket <= 1s
        }
        assert_eq!(h.quantile(0.5), 100.0);
        assert_eq!(h.quantile(0.99), 1_000_000.0);
        assert_eq!(h.count(), 100);
        let want_mean = (90.0 * 80.0 + 10.0 * 400_000.0) / 100.0;
        assert!((h.mean() - want_mean).abs() < 1e-9);
    }

    #[test]
    fn linear_and_unit_builders() {
        let h = AtomicHist::linear(4);
        h.observe(0.0);
        h.observe(3.0);
        h.observe(99.0);
        assert_eq!(h.bucket_counts(), vec![1, 0, 0, 1, 0, 1]);
        let u = AtomicHist::unit_interval(4);
        u.observe(0.1);
        u.observe(0.9);
        assert_eq!(u.bucket_counts(), vec![1, 0, 0, 1]);
    }

    #[test]
    fn concurrent_relaxed_increments_all_land() {
        let h = Arc::new(AtomicHist::latency_us());
        let threads = 4;
        let per = 5_000;
        // THREADS: test-only — `threads` writers observe concurrently,
        // all joined below.
        std::thread::scope(|s| {
            for t in 0..threads {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..per {
                        h.observe(((t * per + i) % 900) as f64);
                    }
                });
            }
        });
        assert_eq!(h.count(), (threads * per) as u64);
        let total: u64 = h.bucket_counts().iter().sum();
        assert_eq!(total, (threads * per) as u64);
        // the CAS'd sum saw every observation exactly once
        let want: f64 = (0..threads * per).map(|i| (i % 900) as f64).sum();
        assert!((h.sum() - want).abs() < 1e-6, "{} vs {want}", h.sum());
    }

    #[test]
    fn snapshot_keys_are_stable() {
        let h = AtomicHist::linear(2);
        h.observe(1.0);
        let s = h.snapshot();
        for key in ["count", "mean", "p50", "p99", "bounds", "counts"] {
            assert!(s.get(key).is_some(), "missing {key}");
        }
        // infinite bound serializes as null, finite ones as numbers
        let bounds = s.get("bounds").unwrap().as_arr().unwrap();
        assert!(matches!(bounds.last(), Some(Json::Null)));
    }
}
