//! Stage-level tracing: span timers over a lock-free ring buffer.
//!
//! Design constraints, in order:
//!
//! 1. **Zero effect on exact values.** Spans read the wall clock and
//!    finished outputs only; they never touch float compute. `obs/` is
//!    outside the EXACT-critical module list (EXACTNESS.md).
//! 2. **Near-zero cost when disabled.** [`span`] is a single relaxed
//!    bool load returning `None`; instrumentation sites pay one branch.
//! 3. **Lock-free when enabled.** Events go into a fixed-capacity ring
//!    of seqlock-style slots whose fields are all atomics: a writer
//!    claims an index with `fetch_add`, marks the slot odd (writing),
//!    stores the fields, then publishes the even sequence number with
//!    `Release`. Readers validate the sequence number before and after
//!    reading; a torn snapshot is detected and skipped. Because every
//!    field is an atomic there are no data races for TSan to flag —
//!    only benign skipped slots under contention.
//!
//! The ring is a *monitoring* artifact: under wrap or contention it
//! drops the oldest events, never blocks a writer.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Pipeline stages a span can label. Discriminants are stable wire
/// values (they appear in trace dumps); append only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Time a job spent in the batcher queue before a worker drained it.
    QueueWait = 0,
    /// Batcher drain: first item to handing the batch to the worker.
    BatchAssemble = 1,
    /// One `DistEngine` kernel launch; args = [m, n, p, engine_id].
    DistKernel = 2,
    /// Nonconformity scoring (`scores_batch`); args = [rows, n_labels].
    MeasureScores = 3,
    /// p-value aggregation over scores; args = [rows, n_labels].
    PValueAgg = 4,
    /// Regression region sweep; args = [rows].
    RegionSweep = 5,
    /// Exchangeability-tester update; args = [batch_len].
    Observe = 6,
    /// Serializing + writing the response to the socket.
    RespWrite = 7,
    /// Online learn (incremental) under the registry write lock.
    Learn = 8,
    /// Online unlearn (decremental) under the registry write lock.
    Unlearn = 9,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BatchAssemble => "batch_assemble",
            Stage::DistKernel => "dist_kernel",
            Stage::MeasureScores => "measure_scores",
            Stage::PValueAgg => "p_value_agg",
            Stage::RegionSweep => "region_sweep",
            Stage::Observe => "observe",
            Stage::RespWrite => "resp_write",
            Stage::Learn => "learn",
            Stage::Unlearn => "unlearn",
        }
    }

    fn from_u8(v: u8) -> Stage {
        match v {
            0 => Stage::QueueWait,
            1 => Stage::BatchAssemble,
            2 => Stage::DistKernel,
            3 => Stage::MeasureScores,
            4 => Stage::PValueAgg,
            5 => Stage::RegionSweep,
            6 => Stage::Observe,
            7 => Stage::RespWrite,
            8 => Stage::Learn,
            _ => Stage::Unlearn,
        }
    }
}

/// Engine identifiers carried in `DistKernel` span args.
pub mod engine_id {
    pub const NATIVE: u64 = 0;
    pub const THREADED: u64 = 1;
    pub const PJRT: u64 = 2;
    pub const STUB: u64 = 3;
}

/// A decoded, validated trace event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Monotonic global event index (wrap-survivor ordering key).
    pub index: u64,
    pub stage: Stage,
    /// Small dense thread id assigned at first span on the thread.
    pub tid: u64,
    /// Span nesting depth on its thread at record time.
    pub depth: u64,
    /// Microseconds since the tracer epoch.
    pub t0_us: u64,
    pub dur_us: u64,
    /// Stage-specific payload; see [`Stage`] docs.
    pub args: [u64; 4],
}

/// One seqlock-style slot. `seq` is 0 (never written), odd (write in
/// progress for index `(seq-1)/2`) or even `2*index+2` (published).
struct Slot {
    seq: AtomicU64,
    stage: AtomicU64,
    tid: AtomicU64,
    depth: AtomicU64,
    t0_us: AtomicU64,
    dur_us: AtomicU64,
    args: [AtomicU64; 4],
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            stage: AtomicU64::new(0),
            tid: AtomicU64::new(0),
            depth: AtomicU64::new(0),
            t0_us: AtomicU64::new(0),
            dur_us: AtomicU64::new(0),
            args: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        }
    }
}

/// Fixed-capacity lock-free ring of trace events.
pub struct TraceRing {
    slots: Vec<Slot>,
    head: AtomicU64,
}

impl TraceRing {
    pub fn new(capacity: usize) -> TraceRing {
        let capacity = capacity.max(1);
        TraceRing {
            slots: (0..capacity).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (not capped at capacity).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Publish one event (lock-free; overwrites the oldest on wrap).
    pub fn record(
        &self,
        stage: Stage,
        tid: u64,
        depth: u64,
        t0_us: u64,
        dur_us: u64,
        args: [u64; 4],
    ) {
        let i = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(i % self.slots.len() as u64) as usize];
        // Mark write-in-progress so readers skip the slot, then publish
        // the even sequence with Release so a reader that sees it also
        // sees the field stores.
        slot.seq.store(2 * i + 1, Ordering::Relaxed);
        slot.stage.store(stage as u8 as u64, Ordering::Relaxed);
        slot.tid.store(tid, Ordering::Relaxed);
        slot.depth.store(depth, Ordering::Relaxed);
        slot.t0_us.store(t0_us, Ordering::Relaxed);
        slot.dur_us.store(dur_us, Ordering::Relaxed);
        for (cell, v) in slot.args.iter().zip(args) {
            cell.store(v, Ordering::Relaxed);
        }
        slot.seq.store(2 * i + 2, Ordering::Release);
    }

    /// Consistent read of one slot, or `None` if it is empty or a
    /// writer raced us on every attempt.
    fn read_slot(&self, slot: &Slot) -> Option<TraceEvent> {
        for _ in 0..4 {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                return None; // never written, or mid-write
            }
            let ev = TraceEvent {
                index: s1 / 2 - 1,
                stage: Stage::from_u8(
                    slot.stage.load(Ordering::Relaxed) as u8
                ),
                tid: slot.tid.load(Ordering::Relaxed),
                depth: slot.depth.load(Ordering::Relaxed),
                t0_us: slot.t0_us.load(Ordering::Relaxed),
                dur_us: slot.dur_us.load(Ordering::Relaxed),
                args: [
                    slot.args[0].load(Ordering::Relaxed),
                    slot.args[1].load(Ordering::Relaxed),
                    slot.args[2].load(Ordering::Relaxed),
                    slot.args[3].load(Ordering::Relaxed),
                ],
            };
            // Order the field loads before the validating re-read.
            std::sync::atomic::fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) == s1 {
                return Some(ev);
            }
        }
        None
    }

    /// All currently readable events with `index >= since`, ordered by
    /// index. Returns the events and the next watermark (pass it back
    /// as `since` to read only newer events).
    pub fn drain_since(&self, since: u64) -> (Vec<TraceEvent>, u64) {
        let mut out: Vec<TraceEvent> = self
            .slots
            .iter()
            .filter_map(|s| self.read_slot(s))
            .filter(|e| e.index >= since)
            .collect();
        out.sort_by_key(|e| e.index);
        let next = out.last().map_or(since, |e| e.index + 1);
        (out, next)
    }

    /// Every currently readable event, ordered by index.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.drain_since(0).0
    }
}

/// Global tracer: the ring plus the epoch all timestamps are relative
/// to.
pub struct Tracer {
    ring: TraceRing,
    epoch: Instant,
}

impl Tracer {
    pub fn ring(&self) -> &TraceRing {
        &self.ring
    }

    fn us_since_epoch(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch)
            .map_or(0, |d| d.as_micros() as u64)
    }
}

static TRACER: OnceLock<Tracer> = OnceLock::new();
static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TID: std::cell::Cell<u64> = const { std::cell::Cell::new(u64::MAX) };
    static DEPTH: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

fn this_tid() -> u64 {
    TID.with(|c| {
        let v = c.get();
        if v != u64::MAX {
            v
        } else {
            let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            c.set(v);
            v
        }
    })
}

/// Install the global tracer with the given ring capacity. First call
/// wins (the ring is shared process state); later calls are no-ops.
/// Tracing still does nothing until [`set_enabled`]`(true)`.
pub fn init(capacity: usize) -> &'static Tracer {
    TRACER.get_or_init(|| Tracer {
        ring: TraceRing::new(capacity),
        epoch: Instant::now(),
    })
}

/// Globally switch span recording on or off.
pub fn set_enabled(on: bool) {
    if on {
        // make sure a ring exists even if init() was never called
        init(DEFAULT_RING_CAPACITY);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

pub const DEFAULT_RING_CAPACITY: usize = 65_536;

/// Is span recording currently on?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The installed tracer, if any.
pub fn tracer() -> Option<&'static Tracer> {
    TRACER.get()
}

/// RAII span: records a complete event with its measured duration on
/// drop.
pub struct SpanGuard {
    stage: Stage,
    start: Instant,
    args: [u64; 4],
}

impl SpanGuard {
    /// Attach stage-specific payload after creation.
    pub fn set_args(&mut self, args: [u64; 4]) {
        self.args = args;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let dur = self.start.elapsed();
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        let depth = DEPTH.with(|d| d.get());
        if let Some(t) = tracer() {
            t.ring.record(
                self.stage,
                this_tid(),
                depth,
                t.us_since_epoch(self.start),
                dur.as_micros() as u64,
                self.args,
            );
        }
    }
}

/// Open a span for `stage`. Returns `None` (one relaxed load, no other
/// work) when tracing is disabled.
#[inline]
pub fn span(stage: Stage) -> Option<SpanGuard> {
    span_args(stage, [0; 4])
}

/// [`span`] with stage-specific payload known up front.
#[inline]
pub fn span_args(stage: Stage, args: [u64; 4]) -> Option<SpanGuard> {
    if !enabled() {
        return None;
    }
    DEPTH.with(|d| d.set(d.get() + 1));
    Some(SpanGuard {
        stage,
        start: Instant::now(),
        args,
    })
}

/// Record a span whose start time is known retroactively (queue wait:
/// the duration is `enqueued.elapsed()` measured at drain).
pub fn record_complete(
    stage: Stage,
    start: Instant,
    dur: Duration,
    args: [u64; 4],
) {
    if !enabled() {
        return;
    }
    if let Some(t) = tracer() {
        let depth = DEPTH.with(|d| d.get());
        t.ring.record(
            stage,
            this_tid(),
            depth,
            t.us_since_epoch(start),
            dur.as_micros() as u64,
            args,
        );
    }
}

/// One event as a JSON object (shared by the Chrome dump and the JSONL
/// writer). Keys are stable wire format.
pub fn event_json(e: &TraceEvent) -> Json {
    Json::obj(vec![
        ("name", Json::Str(e.stage.name().to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(e.t0_us as f64)),
        ("dur", Json::Num(e.dur_us as f64)),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(e.tid as f64)),
        (
            "args",
            Json::obj(vec![
                ("i", Json::Num(e.index as f64)),
                ("depth", Json::Num(e.depth as f64)),
                ("v0", Json::Num(e.args[0] as f64)),
                ("v1", Json::Num(e.args[1] as f64)),
                ("v2", Json::Num(e.args[2] as f64)),
                ("v3", Json::Num(e.args[3] as f64)),
            ]),
        ),
    ])
}

/// Chrome trace format (`chrome://tracing` / Perfetto): an object with
/// a `traceEvents` array of complete ("X") events.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    Json::obj(vec![(
        "traceEvents",
        Json::Arr(events.iter().map(event_json).collect()),
    )])
}

/// Background JSONL trace writer: appends one JSON object per event to
/// `path`, polling the ring on an interval. Used by
/// `repro serve --trace-out`.
pub struct JsonlWriter {
    stop: std::sync::Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl JsonlWriter {
    /// Spawn the writer thread. Fails if the file cannot be created.
    pub fn spawn(path: &std::path::Path) -> std::io::Result<JsonlWriter> {
        use std::io::Write as _;
        let file = std::fs::File::create(path)?;
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        // THREADS: one detachable writer thread; it only polls the
        // lock-free ring and appends to its own file handle, takes no
        // locks, and exits when `stop` flips (joined in `stop()`/Drop).
        let handle = std::thread::spawn(move || {
            let mut out = std::io::BufWriter::new(file);
            let mut watermark = 0u64;
            loop {
                let done = stop2.load(Ordering::Relaxed);
                if let Some(t) = tracer() {
                    let (events, next) = t.ring.drain_since(watermark);
                    watermark = next;
                    for e in &events {
                        let line = event_json(e).encode();
                        if out.write_all(line.as_bytes()).is_err() {
                            return;
                        }
                        let _ = out.write_all(b"\n");
                    }
                    let _ = out.flush();
                }
                if done {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        });
        Ok(JsonlWriter {
            stop,
            handle: Some(handle),
        })
    }

    /// Signal the writer to do a final drain and exit, then join it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for JsonlWriter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ring_records_and_snapshots_in_order() {
        let ring = TraceRing::new(8);
        for i in 0..5u64 {
            ring.record(Stage::DistKernel, 0, 0, i * 10, 5, [i, 0, 0, 0]);
        }
        let evs = ring.snapshot();
        assert_eq!(evs.len(), 5);
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.index, i as u64);
            assert_eq!(e.args[0], i as u64);
            assert_eq!(e.stage, Stage::DistKernel);
        }
        assert_eq!(ring.recorded(), 5);
    }

    #[test]
    fn ring_wrap_keeps_newest() {
        let ring = TraceRing::new(4);
        for i in 0..10u64 {
            ring.record(Stage::QueueWait, 1, 0, i, 1, [0; 4]);
        }
        let evs = ring.snapshot();
        assert_eq!(evs.len(), 4);
        let idx: Vec<u64> = evs.iter().map(|e| e.index).collect();
        assert_eq!(idx, vec![6, 7, 8, 9]);
        assert_eq!(ring.recorded(), 10);
    }

    #[test]
    fn drain_since_watermark_advances() {
        let ring = TraceRing::new(16);
        ring.record(Stage::Observe, 0, 0, 0, 1, [0; 4]);
        ring.record(Stage::Observe, 0, 0, 1, 1, [0; 4]);
        let (evs, next) = ring.drain_since(0);
        assert_eq!(evs.len(), 2);
        assert_eq!(next, 2);
        let (evs2, next2) = ring.drain_since(next);
        assert!(evs2.is_empty());
        assert_eq!(next2, 2);
        ring.record(Stage::Observe, 0, 0, 2, 1, [0; 4]);
        let (evs3, _) = ring.drain_since(next2);
        assert_eq!(evs3.len(), 1);
        assert_eq!(evs3[0].index, 2);
    }

    #[test]
    fn concurrent_writers_never_corrupt_readers() {
        let ring = Arc::new(TraceRing::new(64));
        let writers = 4;
        let per = 10_000;
        // THREADS: test-only — writer threads hammer the ring while the
        // main thread snapshots; all joined at scope end.
        std::thread::scope(|s| {
            for t in 0..writers {
                let ring = ring.clone();
                s.spawn(move || {
                    for i in 0..per {
                        ring.record(
                            Stage::DistKernel,
                            t,
                            0,
                            i,
                            1,
                            [t, i, t + i, 0],
                        );
                    }
                });
            }
            for _ in 0..200 {
                for e in ring.snapshot() {
                    // every consistent read must satisfy the writer's
                    // invariant args[2] == args[0] + args[1]
                    assert_eq!(e.args[2], e.args[0] + e.args[1]);
                    assert!(e.tid < writers || e.tid == 0);
                }
            }
        });
        assert_eq!(ring.recorded(), writers * per);
        // after quiescence every slot is readable
        assert_eq!(ring.snapshot().len(), 64);
    }

    #[test]
    fn chrome_trace_shape() {
        let evs = vec![TraceEvent {
            index: 0,
            stage: Stage::MeasureScores,
            tid: 3,
            depth: 1,
            t0_us: 100,
            dur_us: 40,
            args: [64, 4, 0, 0],
        }];
        let j = chrome_trace_json(&evs);
        let arr = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        let e = &arr[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("measure_scores"));
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("ts").unwrap().as_f64(), Some(100.0));
        assert_eq!(e.get("dur").unwrap().as_f64(), Some(40.0));
        let args = e.get("args").unwrap();
        assert_eq!(args.get("v0").unwrap().as_f64(), Some(64.0));
        // round-trips through the encoder
        let encoded = j.encode();
        assert!(Json::parse(&encoded).is_ok());
    }

    #[test]
    fn stage_names_round_trip() {
        for v in 0..=9u8 {
            let s = Stage::from_u8(v);
            assert_eq!(s as u8, v);
            assert!(!s.name().is_empty());
        }
    }
}
