//! Online validity monitoring.
//!
//! Conformal prediction's contract is *validity*: under
//! exchangeability, the true label falls outside the prediction set
//! with probability at most epsilon. That guarantee is only as good as
//! the exchangeability assumption, so a serving deployment should watch
//! its own live error rate (Angelopoulos et al.'s canonical online
//! health metrics: empirical coverage + prediction-set size).
//!
//! This monitor consumes *finished* p-values only — it runs strictly
//! after the exact scoring path and can never perturb it (EXACTNESS.md;
//! `obs/` is outside the critical-module list).
//!
//! Conventions match `cp::metrics`: a label y is in the prediction set
//! at significance eps iff `p_y > eps`; an error is the truth falling
//! outside the set. Under validity the error rate at eps converges to
//! <= eps, and p-at-truth is (super)uniform on [0,1] — the uniformity
//! histogram makes miscalibration visible at a glance.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::cp::metrics::set_size;
use crate::obs::hist::AtomicHist;
use crate::util::json::Json;

/// Default tracked epsilons when the config does not specify any.
pub const DEFAULT_EPSILONS: [f64; 3] = [0.05, 0.1, 0.2];

/// Error-rate and efficiency counters at one tracked epsilon.
struct EpsilonTrack {
    epsilon: f64,
    /// Labeled predictions seen (only these can be checked for errors).
    labeled: AtomicU64,
    /// Truth outside the prediction set / interval.
    errors: AtomicU64,
    /// Sum of prediction-set sizes over labeled classification
    /// predictions (stays 0 for regression deployments).
    set_size_sum: AtomicU64,
}

impl EpsilonTrack {
    fn new(epsilon: f64) -> EpsilonTrack {
        EpsilonTrack {
            epsilon,
            labeled: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            set_size_sum: AtomicU64::new(0),
        }
    }

    fn snapshot(&self) -> Json {
        let labeled = self.labeled.load(Ordering::Relaxed);
        let errors = self.errors.load(Ordering::Relaxed);
        let sizes = self.set_size_sum.load(Ordering::Relaxed);
        let rate = |num: u64| {
            if labeled == 0 {
                0.0
            } else {
                num as f64 / labeled as f64
            }
        };
        Json::obj(vec![
            ("epsilon", Json::Num(self.epsilon)),
            ("labeled", Json::Num(labeled as f64)),
            ("errors", Json::Num(errors as f64)),
            ("error_rate", Json::Num(rate(errors))),
            ("mean_set_size", Json::Num(rate(sizes))),
        ])
    }
}

/// Per-deployment online validity monitor.
pub struct ValidityMonitor {
    tracks: Vec<EpsilonTrack>,
    /// Primary (first tracked) epsilon: the set-size histogram below is
    /// computed at this significance for *every* prediction, labeled or
    /// not.
    primary: f64,
    set_sizes: AtomicHist,
    /// Regression interval widths (upper - lower), all predictions.
    widths: AtomicHist,
    /// p-at-truth uniformity histogram (20 buckets over [0,1]).
    p_at_truth: AtomicHist,
}

impl ValidityMonitor {
    pub fn new(epsilons: &[f64]) -> ValidityMonitor {
        let eps: Vec<f64> = if epsilons.is_empty() {
            DEFAULT_EPSILONS.to_vec()
        } else {
            epsilons.to_vec()
        };
        ValidityMonitor {
            primary: eps[0],
            tracks: eps.into_iter().map(EpsilonTrack::new).collect(),
            set_sizes: AtomicHist::linear(16),
            widths: AtomicHist::widths(),
            p_at_truth: AtomicHist::unit_interval(20),
        }
    }

    pub fn epsilons(&self) -> Vec<f64> {
        self.tracks.iter().map(|t| t.epsilon).collect()
    }

    /// Record one classification prediction (its full p-value row) and,
    /// when the request carried the true label, check it against every
    /// tracked epsilon.
    pub fn record_classification(&self, ps: &[f64], truth: Option<usize>) {
        self.set_sizes.observe(set_size(ps, self.primary) as f64);
        let Some(y) = truth else { return };
        let Some(&p_true) = ps.get(y) else { return };
        self.p_at_truth.observe(p_true);
        for t in &self.tracks {
            t.labeled.fetch_add(1, Ordering::Relaxed);
            if p_true <= t.epsilon {
                t.errors.fetch_add(1, Ordering::Relaxed);
            }
            t.set_size_sum
                .fetch_add(set_size(ps, t.epsilon) as u64, Ordering::Relaxed);
        }
    }

    /// Record one regression prediction: total interval width at the
    /// request's significance, plus — when the request carried the true
    /// target — the p-value at that target, checked against every
    /// tracked epsilon (truth in the region at eps iff `p_at_y > eps`).
    pub fn record_region(&self, width: f64, p_at_y: Option<f64>) {
        self.widths.observe(width);
        let Some(p) = p_at_y else { return };
        self.p_at_truth.observe(p);
        for t in &self.tracks {
            t.labeled.fetch_add(1, Ordering::Relaxed);
            if p <= t.epsilon {
                t.errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Stable-key JSON snapshot: `per_epsilon`, `set_size_hist`,
    /// `width_hist`, `p_value_hist`.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            (
                "per_epsilon",
                Json::Arr(self.tracks.iter().map(|t| t.snapshot()).collect()),
            ),
            ("set_size_hist", self.set_sizes.snapshot()),
            ("width_hist", self.widths.snapshot()),
            ("p_value_hist", self.p_at_truth.snapshot()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn track(json: &Json, i: usize) -> Json {
        json.get("per_epsilon").unwrap().as_arr().unwrap()[i].clone()
    }

    #[test]
    fn empty_epsilons_fall_back_to_defaults() {
        let v = ValidityMonitor::new(&[]);
        assert_eq!(v.epsilons(), DEFAULT_EPSILONS.to_vec());
    }

    #[test]
    fn classification_errors_counted_per_epsilon() {
        let v = ValidityMonitor::new(&[0.1, 0.5]);
        // truth p-value 0.3: error at eps=0.5, covered at eps=0.1
        v.record_classification(&[0.3, 0.9], Some(0));
        // truth p-value 0.05: error at both
        v.record_classification(&[0.8, 0.05], Some(1));
        // unlabeled: feeds the set-size hist only
        v.record_classification(&[0.8, 0.2], None);
        let s = v.snapshot();
        let t0 = track(&s, 0);
        assert_eq!(t0.get("epsilon").unwrap().as_f64(), Some(0.1));
        assert_eq!(t0.get("labeled").unwrap().as_f64(), Some(2.0));
        assert_eq!(t0.get("errors").unwrap().as_f64(), Some(1.0));
        assert_eq!(t0.get("error_rate").unwrap().as_f64(), Some(0.5));
        let t1 = track(&s, 1);
        assert_eq!(t1.get("errors").unwrap().as_f64(), Some(2.0));
        // sizes at primary eps=0.1: sets {0.3,0.9}->2, {0.8}->1, {0.8,0.2}->2
        let sizes = s.get("set_size_hist").unwrap();
        assert_eq!(sizes.get("count").unwrap().as_f64(), Some(3.0));
        // mean set size at eps=0.1 over the 2 labeled rows: (2+1)/2
        assert_eq!(t0.get("mean_set_size").unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn region_widths_and_p_at_y() {
        let v = ValidityMonitor::new(&[0.1]);
        v.record_region(3.0, Some(0.04)); // error at 0.1
        v.record_region(2.0, Some(0.7)); // covered
        v.record_region(5.0, None); // unlabeled
        let s = v.snapshot();
        let t = track(&s, 0);
        assert_eq!(t.get("labeled").unwrap().as_f64(), Some(2.0));
        assert_eq!(t.get("errors").unwrap().as_f64(), Some(1.0));
        let w = s.get("width_hist").unwrap();
        assert_eq!(w.get("count").unwrap().as_f64(), Some(3.0));
        let p = s.get("p_value_hist").unwrap();
        assert_eq!(p.get("count").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn uniform_p_values_give_near_epsilon_error_rate() {
        let v = ValidityMonitor::new(&[0.1]);
        // 1000 evenly spread p-at-truth values: error rate -> ~0.1
        for i in 0..1000 {
            let p = (i as f64 + 0.5) / 1000.0;
            v.record_classification(&[p], Some(0));
        }
        let t = track(&v.snapshot(), 0);
        let rate = t.get("error_rate").unwrap().as_f64().unwrap();
        assert!((rate - 0.1).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn out_of_range_truth_is_ignored() {
        let v = ValidityMonitor::new(&[0.1]);
        v.record_classification(&[0.5, 0.5], Some(7));
        let t = track(&v.snapshot(), 0);
        assert_eq!(t.get("labeled").unwrap().as_f64(), Some(0.0));
    }
}
