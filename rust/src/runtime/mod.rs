//! PJRT runtime — the AOT bridge between the Rust coordinator and the
//! JAX/Pallas kernels.
//!
//! `make artifacts` (build time, Python) lowers every L2 entry point of
//! `python/compile/model.py` to HLO *text* at a grid of padded shape
//! buckets and writes `artifacts/manifest.json`. At run time this module
//! loads the manifest, compiles artifacts on the PJRT CPU client
//! on first use (caching the executables), and exposes typed entry
//! points plus a [`DistEngine`] implementation so the optimized CP
//! measures can run their distance hot-spot through the Pallas kernels.
//!
//! HLO text (not serialized protos) is the interchange format: jax>=0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Padding contract (mirrors `model.py`): feature dim is zero-padded to
//! the bucket p (zero-padding both operands leaves pairwise distances
//! unchanged); row counts are padded with zero rows and the caller reads
//! back only the first n outputs.

pub mod registry;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtEngine, PjrtRuntime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtEngine, PjrtRuntime};

pub use registry::{Manifest, M_BUCKETS, ROW_BUCKETS};
