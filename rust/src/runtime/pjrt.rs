//! PJRT-backed implementation (requires the `pjrt` cargo feature and
//! the `xla` bindings crate from the rust_pallas toolchain image).

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::engine::DistEngine;
use crate::runtime::registry::Manifest;

/// A PJRT CPU runtime with a lazily-populated executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: std::path::PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

// The auto-traits are blocked only by the raw PJRT_Client pointer inside
// `xla::PjRtClient`; every Rust-side field is Send + Sync on its own
// (PathBuf, Manifest, Mutex<HashMap<..>>).
//
// SAFETY: Send — `client` is an opaque owned handle; the PJRT C API
// permits using and destroying a client from a thread other than its
// creator, and no field borrows thread-local state, so moving is sound.
unsafe impl Send for PjrtRuntime {}
// SAFETY: Sync — `&self` calls reach PJRT compile/execute, documented
// thread-compatible for CPU clients, plus `cache`, whose Mutex (see the
// runtime.exec_cache sites) serializes the only Rust-side mutation.
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Open the artifact directory (reads manifest.json, creates the
    /// PJRT CPU client; compiles nothing yet).
    pub fn open(dir: &str) -> Result<Self> {
        let manifest = Manifest::load(dir)
            .with_context(|| format!("loading {dir}/manifest.json"))?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            dir: dir.into(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute artifact `name` on f32 literals; returns the first tuple
    /// element flattened to f32 (all model.py entry points return
    /// 1-tuples except lssvm_update, which uses [`Self::run_multi`]).
    pub fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<f32>> {
        let outs = self.run_raw(name, args, 1)?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Execute and unpack an `n_outputs`-tuple.
    pub fn run_multi(
        &self,
        name: &str,
        args: &[xla::Literal],
        n_outputs: usize,
    ) -> Result<Vec<Vec<f32>>> {
        self.run_raw(name, args, n_outputs)
    }

    fn run_raw(
        &self,
        name: &str,
        args: &[xla::Literal],
        n_outputs: usize,
    ) -> Result<Vec<Vec<f32>>> {
        // LOCK-ORDER: runtime.exec_cache — held across compile+execute;
        // innermost (nothing else is acquired under it), may itself be
        // entered under coordinator.registry.
        let mut cache = self.cache.lock().unwrap();
        if !cache.contains_key(name) {
            let file = self
                .manifest
                .file_for(name)
                .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?;
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            cache.insert(name.to_string(), exe);
        }
        let exe = cache.get(name).unwrap();
        let mut result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // model.py lowers with return_tuple=True
        let tuple = result
            .decompose_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if tuple.len() < n_outputs {
            bail!("{name}: expected {n_outputs} outputs, got {}", tuple.len());
        }
        tuple
            .into_iter()
            .take(n_outputs)
            .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Number of executables compiled so far (diagnostics / tests).
    pub fn compiled_count(&self) -> usize {
        // LOCK-ORDER: runtime.exec_cache — read-only size peek.
        self.cache.lock().unwrap().len()
    }

    // ---------------- typed entry points -----------------------------

    /// Distance row via the `dist_row_n*_p*` Pallas artifact.
    pub fn dist_row_sq_f32(
        &self,
        x: &[f64],
        rows: &[f64],
        p: usize,
    ) -> Result<Vec<f64>> {
        let n = rows.len() / p;
        let (n_pad, p_pad) = self.manifest.bucket(n, p)?;
        let name = format!("dist_row_n{n_pad}_p{p_pad}");
        let x_lit = pad_literal(x, 1, p, 1, p_pad)?;
        let b_lit = pad_literal(rows, n, p, n_pad, p_pad)?;
        let out = self.run(&name, &[x_lit, b_lit])?;
        Ok(out[..n].iter().map(|&v| v as f64).collect())
    }

    /// Gaussian kernel row via the fused `kde_row_*` artifact.
    pub fn kde_row_f32(
        &self,
        x: &[f64],
        rows: &[f64],
        p: usize,
        h2: f64,
    ) -> Result<Vec<f64>> {
        let n = rows.len() / p;
        let (n_pad, p_pad) = self.manifest.bucket(n, p)?;
        let name = format!("kde_row_n{n_pad}_p{p_pad}");
        let x_lit = pad_literal(x, 1, p, 1, p_pad)?;
        let b_lit = pad_literal(rows, n, p, n_pad, p_pad)?;
        let h_lit = scalar_literal(h2)?;
        let out = self.run(&name, &[x_lit, b_lit, h_lit])?;
        Ok(out[..n].iter().map(|&v| v as f64).collect())
    }

    /// Full m x n squared-distance matrix via the `dist_matrix_*`
    /// Pallas artifact (one launch per test batch). Returns the
    /// unpadded m x n row-major matrix.
    pub fn dist_matrix_sq_f32(
        &self,
        xs: &[f64],
        rows: &[f64],
        p: usize,
    ) -> Result<Vec<f64>> {
        let m = xs.len() / p;
        let n = rows.len() / p;
        let (n_pad, p_pad) = self.manifest.bucket(n, p)?;
        let m_pad = self.manifest.bucket_m(m)?;
        let name = format!("dist_matrix_m{m_pad}_n{n_pad}_p{p_pad}");
        let a_lit = pad_literal(xs, m, p, m_pad, p_pad)?;
        let b_lit = pad_literal(rows, n, p, n_pad, p_pad)?;
        let out = self.run(&name, &[a_lit, b_lit])?;
        let mut res = Vec::with_capacity(m * n);
        for i in 0..m {
            res.extend(out[i * n_pad..i * n_pad + n].iter().map(|&v| v as f64));
        }
        Ok(res)
    }

    /// Fused Simplified-k-NN score update (§3.1) in one PJRT call.
    #[allow(clippy::too_many_arguments)]
    pub fn knn_update_f32(
        &self,
        x: &[f64],
        rows: &[f64],
        p: usize,
        alpha_prov: &[f64],
        delta_k: &[f64],
        same_label: &[f64],
    ) -> Result<Vec<f64>> {
        let n = rows.len() / p;
        let (n_pad, p_pad) = self.manifest.bucket(n, p)?;
        let name = format!("knn_update_n{n_pad}_p{p_pad}");
        let x_lit = pad_literal(x, 1, p, 1, p_pad)?;
        let b_lit = pad_literal(rows, n, p, n_pad, p_pad)?;
        // phantom rows: same_label = 0 makes the update a no-op for them
        let a_lit = pad_vec_literal(alpha_prov, n_pad)?;
        let d_lit = pad_vec_literal(delta_k, n_pad)?;
        let s_lit = pad_vec_literal(same_label, n_pad)?;
        let out = self.run(&name, &[x_lit, b_lit, a_lit, d_lit, s_lit])?;
        Ok(out[..n].iter().map(|&v| v as f64).collect())
    }
}

/// f64 row-major (n x p) -> zero-padded f32 literal of (n_pad x p_pad).
fn pad_literal(
    data: &[f64],
    n: usize,
    p: usize,
    n_pad: usize,
    p_pad: usize,
) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), n * p);
    let mut buf = vec![0f32; n_pad * p_pad];
    for i in 0..n {
        for j in 0..p {
            buf[i * p_pad + j] = data[i * p + j] as f32;
        }
    }
    xla::Literal::vec1(&buf)
        .reshape(&[n_pad as i64, p_pad as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// f64 vector -> zero-padded f32 rank-1 literal of length n_pad.
fn pad_vec_literal(data: &[f64], n_pad: usize) -> Result<xla::Literal> {
    let mut buf = vec![0f32; n_pad];
    for (b, &v) in buf.iter_mut().zip(data) {
        *b = v as f32;
    }
    Ok(xla::Literal::vec1(&buf))
}

/// scalar -> (1,1) f32 literal.
fn scalar_literal(v: f64) -> Result<xla::Literal> {
    xla::Literal::vec1(&[v as f32])
        .reshape(&[1, 1])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// [`DistEngine`] adapter: lets the optimized measures run their
/// distance hot-spot through the AOT Pallas kernels. Falls back to the
/// native loops when inputs exceed every bucket.
pub struct PjrtEngine {
    rt: std::sync::Arc<PjrtRuntime>,
}

impl PjrtEngine {
    pub fn new(rt: std::sync::Arc<PjrtRuntime>) -> Self {
        PjrtEngine { rt }
    }
}

impl DistEngine for PjrtEngine {
    fn dist_row_sq(&self, x: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
        match self.rt.dist_row_sq_f32(x, rows, p) {
            Ok(v) => out.copy_from_slice(&v),
            Err(_) => crate::linalg::distance::dist_row_sq_into(x, rows, p, out),
        }
    }

    fn kde_row(&self, x: &[f64], rows: &[f64], p: usize, h2: f64, out: &mut [f64]) {
        match self.rt.kde_row_f32(x, rows, p, h2) {
            Ok(v) => out.copy_from_slice(&v),
            Err(_) => {
                crate::linalg::distance::dist_row_sq_into(x, rows, p, out);
                for v in out.iter_mut() {
                    *v = (-*v / (2.0 * h2)).exp();
                }
            }
        }
    }

    fn dist_matrix_sq(&self, xs: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
        if p == 0 || xs.is_empty() || rows.is_empty() {
            return;
        }
        let _span = crate::linalg::engine::kernel_span(
            crate::obs::trace::engine_id::PJRT,
            xs,
            rows,
            p,
        );
        match self.rt.dist_matrix_sq_f32(xs, rows, p) {
            Ok(v) => out.copy_from_slice(&v),
            Err(_) => {
                crate::linalg::distance::dist_matrix_sq_into(xs, rows, p, out)
            }
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
