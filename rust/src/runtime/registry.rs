//! Artifact manifest + shape-bucket registry.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing
//! every AOT HLO module (name, file, argument shapes). This module
//! parses it (in-tree JSON) and answers "which padded bucket serves a
//! request of n rows x p features".

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Row-count buckets compiled by aot.py (ascending).
pub const ROW_BUCKETS: [usize; 4] = [256, 1024, 4096, 16384];
/// Feature-dim buckets compiled by aot.py (ascending).
pub const P_BUCKETS: [usize; 2] = [32, 784];
/// Test-batch row buckets for the `dist_matrix_*` artifacts (ascending;
/// multiples of the 128 Pallas tile).
pub const M_BUCKETS: [usize; 2] = [128, 512];

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: String,
    /// argument shapes as listed in the manifest
    pub arg_shapes: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = std::path::Path::new(dir).join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;
        let Json::Obj(map) = root else {
            bail!("manifest root must be an object");
        };
        let mut artifacts = BTreeMap::new();
        for (name, entry) in map {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .with_context(|| format!("{name}: missing file"))?
                .to_string();
            let arg_shapes = entry
                .get("args")
                .and_then(Json::as_arr)
                .map(|args| {
                    args.iter()
                        .filter_map(|a| a.get("shape"))
                        .filter_map(Json::as_arr)
                        .map(|dims| {
                            dims.iter().filter_map(Json::as_usize).collect()
                        })
                        .collect()
                })
                .unwrap_or_default();
            artifacts.insert(name, ArtifactInfo { file, arg_shapes });
        }
        Ok(Manifest { artifacts })
    }

    pub fn file_for(&self, name: &str) -> Option<&str> {
        self.artifacts.get(name).map(|a| a.file.as_str())
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// Smallest test-batch bucket covering `m` rows, if any.
    pub fn bucket_m(&self, m: usize) -> Result<usize> {
        M_BUCKETS
            .iter()
            .copied()
            .find(|&b| m <= b)
            .with_context(|| format!("batch size {m} exceeds every bucket"))
    }

    /// Smallest compiled bucket covering (n, p), if any.
    pub fn bucket(&self, n: usize, p: usize) -> Result<(usize, usize)> {
        let p_pad = P_BUCKETS
            .iter()
            .copied()
            .find(|&b| p <= b)
            .with_context(|| format!("feature dim {p} exceeds every bucket"))?;
        let n_pad = ROW_BUCKETS
            .iter()
            .copied()
            .find(|&b| n <= b)
            .with_context(|| format!("row count {n} exceeds every bucket"))?;
        Ok((n_pad, p_pad))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "dist_row_n256_p32": {
            "file": "dist_row_n256_p32.hlo.txt",
            "args": [
                {"shape": [1, 32], "dtype": "float32"},
                {"shape": [256, 32], "dtype": "float32"}
            ],
            "sha256": "abc", "bytes": 100
        }
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(
            m.file_for("dist_row_n256_p32"),
            Some("dist_row_n256_p32.hlo.txt")
        );
        let a = &m.artifacts["dist_row_n256_p32"];
        assert_eq!(a.arg_shapes, vec![vec![1, 32], vec![256, 32]]);
    }

    #[test]
    fn bucket_m_selection() {
        let m = Manifest::default();
        assert_eq!(m.bucket_m(1).unwrap(), 128);
        assert_eq!(m.bucket_m(128).unwrap(), 128);
        assert_eq!(m.bucket_m(129).unwrap(), 512);
        assert!(m.bucket_m(513).is_err());
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::default();
        assert_eq!(m.bucket(10, 30).unwrap(), (256, 32));
        assert_eq!(m.bucket(256, 32).unwrap(), (256, 32));
        assert_eq!(m.bucket(257, 33).unwrap(), (1024, 784));
        assert_eq!(m.bucket(16384, 784).unwrap(), (16384, 784));
        assert!(m.bucket(16385, 30).is_err());
        assert!(m.bucket(10, 1000).is_err());
    }
}
