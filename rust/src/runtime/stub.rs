//! API-compatible stub for the PJRT runtime, compiled when the `pjrt`
//! cargo feature is off (the offline default: the `xla` bindings crate
//! is only available inside the rust_pallas toolchain image).
//!
//! Every constructor fails cleanly, so all call sites — the engine
//! factory, the benches, and the integration tests — take their
//! documented "artifacts unavailable" fallback path: the native Rust
//! kernels. The typed entry points exist so code written against the
//! real runtime type-checks unchanged.

use anyhow::{bail, Result};

use crate::linalg::engine::DistEngine;
use crate::runtime::registry::Manifest;

/// Stub PJRT runtime: [`PjrtRuntime::open`] always fails.
pub struct PjrtRuntime {
    manifest: Manifest,
}

impl PjrtRuntime {
    /// Always fails: PJRT support is not compiled in. Build with
    /// `--features pjrt` (and the `xla` dependency) for the real thing.
    pub fn open(_dir: &str) -> Result<Self> {
        bail!("PJRT support not compiled in (enable the `pjrt` feature)")
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Number of executables compiled so far (always 0 for the stub).
    pub fn compiled_count(&self) -> usize {
        0
    }

    // ---------------- typed entry points -----------------------------
    // Unreachable in practice (open() never succeeds) but kept
    // signature-compatible with the real runtime.

    pub fn dist_row_sq_f32(
        &self,
        _x: &[f64],
        _rows: &[f64],
        _p: usize,
    ) -> Result<Vec<f64>> {
        bail!("PJRT support not compiled in")
    }

    pub fn kde_row_f32(
        &self,
        _x: &[f64],
        _rows: &[f64],
        _p: usize,
        _h2: f64,
    ) -> Result<Vec<f64>> {
        bail!("PJRT support not compiled in")
    }

    pub fn dist_matrix_sq_f32(
        &self,
        _xs: &[f64],
        _rows: &[f64],
        _p: usize,
    ) -> Result<Vec<f64>> {
        bail!("PJRT support not compiled in")
    }

    pub fn knn_update_f32(
        &self,
        _x: &[f64],
        _rows: &[f64],
        _p: usize,
        _alpha_prov: &[f64],
        _delta_k: &[f64],
        _same_label: &[f64],
    ) -> Result<Vec<f64>> {
        bail!("PJRT support not compiled in")
    }
}

/// Stub engine: delegates every kernel to the native loops.
pub struct PjrtEngine {
    _rt: std::sync::Arc<PjrtRuntime>,
}

impl PjrtEngine {
    pub fn new(rt: std::sync::Arc<PjrtRuntime>) -> Self {
        PjrtEngine { _rt: rt }
    }
}

impl DistEngine for PjrtEngine {
    fn dist_row_sq(&self, x: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
        crate::linalg::distance::dist_row_sq_into(x, rows, p, out);
    }

    fn dist_matrix_sq(&self, xs: &[f64], rows: &[f64], p: usize, out: &mut [f64]) {
        let _span = crate::linalg::engine::kernel_span(
            crate::obs::trace::engine_id::STUB,
            xs,
            rows,
            p,
        );
        crate::linalg::distance::dist_matrix_sq_into(xs, rows, p, out);
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_open_fails_cleanly() {
        let e = PjrtRuntime::open("artifacts").unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
