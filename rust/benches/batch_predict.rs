//! Bench: the batched scoring engine vs the per-pair loop — the
//! serving-path speedup this crate's `scores_batch` exists for.
//!
//! A 64-object, 4-label batch (the acceptance shape) is scored two
//! ways for each measure family:
//!
//! * **per-pair** — `scores(x, y)` for every (object, label) pair, the
//!   pre-batching serving path: one distance/kernel row per pair;
//! * **batched** — one `scores_batch(xs, labels)` call: one row per
//!   object, reused across labels (and, for the standard k-NN/KDE
//!   variants, one row per *training* point per batch).
//!
//! Outputs are asserted bit-identical before timing, then each path is
//! timed and the speedup printed. LS-SVM is binary-only, so it runs on
//! a 2-label dataset at the same batch width.

use std::time::Duration;

use exact_cp::config::{MeasureConfig, MeasureKind};
use exact_cp::coordinator::factory::build_measure;
use exact_cp::cp::measure::CpMeasure;
use exact_cp::data::{make_classification, ClassificationSpec, Label};

fn assert_batch_matches(
    m: &dyn CpMeasure,
    xs: &[&[f64]],
    labels: &[Label],
) {
    let batch = m.scores_batch(xs, labels);
    assert_eq!(batch.len(), xs.len() * labels.len());
    for (xi, x) in xs.iter().enumerate() {
        for (li, &y) in labels.iter().enumerate() {
            let single = m.scores(x, y);
            let got = &batch[xi * labels.len() + li];
            assert_eq!(got.test.to_bits(), single.test.to_bits());
            for (a, b) in got.train.iter().zip(&single.train) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}

fn bench_measure(
    name: &str,
    m: &dyn CpMeasure,
    xs: &[&[f64]],
    labels: &[Label],
    budget: Duration,
) {
    assert_batch_matches(m, xs, labels);
    let t_pair = exact_cp::bench_harness::timing::microbench(
        &format!("{name}: per-pair loop"),
        budget,
        || {
            let mut acc = 0.0;
            for x in xs {
                for &y in labels {
                    acc += m.scores(x, y).test;
                }
            }
            acc
        },
    );
    let t_batch = exact_cp::bench_harness::timing::microbench(
        &format!("{name}: scores_batch"),
        budget,
        || {
            m.scores_batch(xs, labels)
                .iter()
                .map(|s| s.test)
                .sum::<f64>()
        },
    );
    println!("{name}: batched speedup {:.2}x", t_pair / t_batch);
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let budget = Duration::from_millis(if quick { 150 } else { 1000 });
    let n = if quick { 256 } else { 512 };
    let m_test = 64usize;
    let cfg = MeasureConfig::default();

    // 4-label workload for the label-generic measures
    let ds4 = make_classification(
        &ClassificationSpec {
            n_samples: n,
            n_classes: 4,
            n_informative: 3,
            ..Default::default()
        },
        1,
    );
    let probe4 = make_classification(
        &ClassificationSpec {
            n_samples: m_test,
            n_classes: 4,
            n_informative: 3,
            ..Default::default()
        },
        2,
    );
    let xs4: Vec<&[f64]> = (0..probe4.n()).map(|i| probe4.row(i)).collect();
    let labels4: Vec<Label> = (0..4).collect();

    println!(
        "== batch_predict: {} objects x {} labels at n={n} ==",
        m_test,
        labels4.len()
    );
    for kind in [MeasureKind::SimplifiedKnn, MeasureKind::Knn, MeasureKind::Kde]
    {
        let mut m = build_measure(kind, &cfg, None);
        m.fit(&ds4);
        bench_measure(&m.name(), m.as_ref(), &xs4, &labels4, budget);
    }

    // LS-SVM is binary: same batch width, 2 labels
    let ds2 = make_classification(
        &ClassificationSpec {
            n_samples: n,
            ..Default::default()
        },
        3,
    );
    let probe2 = make_classification(
        &ClassificationSpec {
            n_samples: m_test,
            ..Default::default()
        },
        4,
    );
    let xs2: Vec<&[f64]> = (0..probe2.n()).map(|i| probe2.row(i)).collect();
    let labels2: Vec<Label> = vec![0, 1];
    let mut m = build_measure(MeasureKind::LsSvm, &cfg, None);
    m.fit(&ds2);
    bench_measure(&m.name(), m.as_ref(), &xs2, &labels2, budget);

    trace_overhead(&ds4, &xs4, &labels4, &cfg, budget, quick);
}

/// Observability acceptance gate: the batched scoring hot path with
/// span tracing ON must stay within 5% of the untraced time. Timed on
/// the busiest measure (simplified k-NN hits the dist-kernel, scoring
/// and p-value-agg spans). The assertion runs in full mode only —
/// BENCH_QUICK budgets are too short for a stable ratio.
fn trace_overhead(
    ds: &exact_cp::data::Dataset,
    xs: &[&[f64]],
    labels: &[Label],
    cfg: &MeasureConfig,
    budget: Duration,
    quick: bool,
) {
    use exact_cp::obs::trace;

    let mut m = build_measure(MeasureKind::SimplifiedKnn, cfg, None);
    m.fit(ds);
    let run = || {
        m.scores_batch(xs, labels)
            .iter()
            .map(|s| s.test)
            .sum::<f64>()
    };
    trace::set_enabled(false);
    let t_off = exact_cp::bench_harness::timing::microbench(
        "sknn scores_batch: tracing off",
        budget,
        run,
    );
    trace::init(trace::DEFAULT_RING_CAPACITY);
    trace::set_enabled(true);
    let t_on = exact_cp::bench_harness::timing::microbench(
        "sknn scores_batch: tracing on",
        budget,
        run,
    );
    trace::set_enabled(false);
    let overhead = t_on / t_off - 1.0;
    println!("tracing overhead: {:+.2}%", overhead * 100.0);
    if !quick {
        assert!(
            overhead <= 0.05,
            "span instrumentation overhead {:.2}% exceeds the 5% budget",
            overhead * 100.0
        );
    }
}
