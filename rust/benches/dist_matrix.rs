//! Bench: the tiled m x n distance-matrix kernel vs the per-row loop.
//!
//! This is the acceptance gate for the batch distance kernel
//! (ROADMAP "Batch-level distance kernels"): at the serving shape
//! m=64 test objects, n=2000 training rows, p=32 features, the tiled
//! `dist_matrix_sq_into` must be at least 2x faster than calling
//! `dist_row_sq_into` once per test row. Before timing, the bench
//! asserts the exactness contract: the matrix is bit-identical to the
//! stacked per-row outputs, at every worker count.
//!
//! Results are written to `BENCH_dist_matrix.json`. Smoke mode
//! (`BENCH_QUICK=1` or a `--test` argument, used by CI) runs the
//! bit-identity asserts and emits the JSON but skips the 2x gate —
//! shared CI runners make wall-clock gates flaky.

use std::time::Duration;

use exact_cp::linalg::{
    dist_matrix_sq_into, dist_matrix_sq_into_workers, dist_row_sq_into,
};

const M: usize = 64;
const N: usize = 2000;
const P: usize = 32;

/// xorshift fill, same generator the linalg unit tests use.
fn fill(seed: u64, len: usize) -> Vec<f64> {
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    (0..len)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s >> 11) as f64 / (1u64 << 53) as f64 - 0.5
        })
        .collect()
}

fn per_row_loop(xs: &[f64], rows: &[f64], out: &mut [f64]) {
    let n = rows.len() / P;
    for (x, o) in xs.chunks_exact(P).zip(out.chunks_exact_mut(n)) {
        dist_row_sq_into(x, rows, P, o);
    }
}

fn main() {
    let smoke = std::env::var("BENCH_QUICK").is_ok()
        || std::env::args().any(|a| a == "--test");
    let budget = Duration::from_millis(if smoke { 150 } else { 1500 });

    let xs = fill(1, M * P);
    let rows = fill(2, N * P);

    // ---- exactness contract (always enforced) -----------------------
    let mut rowwise = vec![0.0; M * N];
    per_row_loop(&xs, &rows, &mut rowwise);
    let mut matrix = vec![0.0; M * N];
    dist_matrix_sq_into(&xs, &rows, P, &mut matrix);
    for (i, (a, b)) in matrix.iter().zip(&rowwise).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "entry {i} diverges");
    }
    for workers in [1usize, 2, 4] {
        let mut par = vec![0.0; M * N];
        dist_matrix_sq_into_workers(&xs, &rows, P, workers, &mut par);
        for (i, (a, b)) in par.iter().zip(&rowwise).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "workers={workers}: entry {i} diverges"
            );
        }
    }
    println!("exactness: matrix == stacked rows, workers {{1,2,4}} (bitwise)");

    // ---- timing -----------------------------------------------------
    println!("== dist_matrix: m={M} x n={N} at p={P} ==");
    let mut out = vec![0.0; M * N];
    let t_rows = exact_cp::bench_harness::timing::microbench(
        "per-row loop (dist_row_sq_into x m)",
        budget,
        || {
            per_row_loop(&xs, &rows, &mut out);
            out[0]
        },
    );
    let t_matrix = exact_cp::bench_harness::timing::microbench(
        "tiled matrix (dist_matrix_sq_into)",
        budget,
        || {
            dist_matrix_sq_into(&xs, &rows, P, &mut out);
            out[0]
        },
    );
    let speedup = t_rows / t_matrix;
    println!("dist_matrix: tiled speedup {speedup:.2}x");

    let json = format!(
        "{{\n  \"bench\": \"dist_matrix\",\n  \"m\": {M},\n  \"n\": {N},\n  \
         \"p\": {P},\n  \"per_row_s\": {t_rows:.9},\n  \
         \"matrix_s\": {t_matrix:.9},\n  \"speedup\": {speedup:.4},\n  \
         \"smoke\": {smoke}\n}}\n"
    );
    std::fs::write("BENCH_dist_matrix.json", &json)
        .expect("writing BENCH_dist_matrix.json");
    println!("wrote BENCH_dist_matrix.json");

    if !smoke {
        assert!(
            speedup >= 2.0,
            "tiled kernel must be >= 2x the per-row loop, got {speedup:.2}x"
        );
    }
}
