//! Bench: App. C.5 — one step of the online IID test (p-value for a new
//! observation + incremental learn) at a fixed history size.

use std::time::Duration;

use exact_cp::bench_harness::timing::microbench;
use exact_cp::cp::measure::CpMeasure;
use exact_cp::cp::pvalue::smoothed_p_value;
use exact_cp::data::{Dataset, Rng};
use exact_cp::measures::knn::{KnnOptimized, KnnStandard};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let budget = Duration::from_millis(if quick { 200 } else { 1500 });
    let n = if quick { 256 } else { 2000 };
    let dim = 5;
    let mut rng = Rng::seed_from(1);
    let xs: Vec<f64> = (0..n * dim).map(|_| rng.normal()).collect();
    let history = Dataset::new(xs, vec![0; n], dim, 1);
    let x_new: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();

    println!("== iid bench: one online-test step with history n={n} ==");

    let mut opt = KnnOptimized::new(5, true);
    opt.fit(&history);
    microbench("optimized: p-value (O(n))", budget, || {
        smoothed_p_value(&opt.scores(&x_new, 0), 0.5)
    });

    let n_std = (n / 8).max(64);
    let small = Dataset::new(
        history.x[..n_std * dim].to_vec(),
        vec![0; n_std],
        dim,
        1,
    );
    let mut std_m = KnnStandard::new(5, true);
    std_m.fit(&small);
    microbench(
        &format!("standard: p-value (O(n^2), n={n_std})"),
        budget,
        || smoothed_p_value(&std_m.scores(&x_new, 0), 0.5),
    );
}
