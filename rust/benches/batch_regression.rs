//! Bench: batched regression intervals vs the per-object loop — the
//! serving-path speedup `coefficients_batch` exists for.
//!
//! A 64-object batch (the acceptance shape) is pushed through
//! `predict_region` two ways for each CP regressor:
//!
//! * **per-object** — `predict_region(x, eps)` per test object: the
//!   standard k-NN variant recomputes the O(n^2) neighbour-statistics
//!   pass per object, ridge recomputes `M0 (X^T Y)` per object;
//! * **batched** — one `predict_region_batch(xs, eps)` call: the
//!   test-independent work is hoisted once per batch.
//!
//! Outputs are asserted bit-identical before timing (the exactness
//! contract of `src/regression/`), then each path is timed and the
//! speedup printed. The standard k-NN variant must clear 2x at batch 64
//! — its per-object O(n^2) term is the whole point of the hoist; the
//! optimized variant and ridge only save a row/matvec per object, so
//! their speedups are reported but not gated.

use std::time::Duration;

use exact_cp::data::{make_regression, RegressionSpec};
use exact_cp::regression::{
    CpRegressor, KnnRegressorOptimized, KnnRegressorStandard, RidgeCp,
};

fn assert_batch_matches(r: &dyn CpRegressor, xs: &[&[f64]], eps: f64) {
    let batch = r.coefficients_batch(xs);
    assert_eq!(batch.len(), xs.len());
    for (got, &x) in batch.iter().zip(xs) {
        let (sc, sa, sb) = r.coefficients(x);
        assert_eq!(got.1.to_bits(), sa.to_bits());
        assert_eq!(got.2.to_bits(), sb.to_bits());
        assert_eq!(got.0.len(), sc.len());
        for (u, v) in got.0.iter().zip(&sc) {
            assert_eq!(u.0.to_bits(), v.0.to_bits());
            assert_eq!(u.1.to_bits(), v.1.to_bits());
        }
    }
    let regions = r.predict_region_batch(xs, eps);
    for (got, &x) in regions.iter().zip(xs) {
        assert_eq!(*got, r.predict_region(x, eps));
    }
}

/// Times both paths and returns the speedup factor.
fn bench_regressor(
    r: &dyn CpRegressor,
    xs: &[&[f64]],
    eps: f64,
    budget: Duration,
) -> f64 {
    let name = r.name();
    assert_batch_matches(r, xs, eps);
    let t_single = exact_cp::bench_harness::timing::microbench(
        &format!("{name}: per-object loop"),
        budget,
        || {
            xs.iter()
                .map(|&x| r.predict_region(x, eps).total_width())
                .sum::<f64>()
        },
    );
    let t_batch = exact_cp::bench_harness::timing::microbench(
        &format!("{name}: predict_region_batch"),
        budget,
        || {
            r.predict_region_batch(xs, eps)
                .iter()
                .map(|reg| reg.total_width())
                .sum::<f64>()
        },
    );
    let speedup = t_single / t_batch;
    println!("{name}: batched speedup {speedup:.2}x");
    speedup
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let budget = Duration::from_millis(if quick { 150 } else { 1000 });
    let n = if quick { 256 } else { 512 };
    let m_test = 64usize;
    let eps = 0.1;

    let train = make_regression(
        &RegressionSpec {
            n_samples: n,
            n_features: 6,
            n_informative: 4,
            noise: 4.0,
        },
        1,
    );
    let probe = make_regression(
        &RegressionSpec {
            n_samples: m_test,
            n_features: 6,
            n_informative: 4,
            noise: 4.0,
        },
        2,
    );
    let xs: Vec<&[f64]> = (0..probe.n()).map(|i| probe.row(i)).collect();

    println!(
        "== batch_regression: {m_test} objects at n={n}, eps={eps} =="
    );
    let mut standard = KnnRegressorStandard::new(5);
    standard.fit(&train);
    let speedup = bench_regressor(&standard, &xs, eps, budget);
    assert!(
        speedup >= 2.0,
        "standard k-NN batch speedup {speedup:.2}x below the 2x bar"
    );

    let mut optimized = KnnRegressorOptimized::new(5);
    optimized.fit(&train);
    bench_regressor(&optimized, &xs, eps, budget);

    let mut ridge = RidgeCp::new(1.0);
    ridge.fit(&train);
    bench_regressor(&ridge, &xs, eps, budget);
}
