//! Bench: Figure 3 — training (precomputation) time of the optimized
//! measures at a fixed n.

use std::time::Duration;

use exact_cp::bench_harness::timing::microbench;
use exact_cp::config::{MeasureConfig, MeasureKind};
use exact_cp::coordinator::factory::build_measure;
use exact_cp::data::{make_classification, ClassificationSpec};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let budget = Duration::from_millis(if quick { 200 } else { 1500 });
    let n = if quick { 256 } else { 1024 };
    let cfg = MeasureConfig::default();
    let ds = make_classification(
        &ClassificationSpec {
            n_samples: n,
            ..Default::default()
        },
        1,
    );
    println!("== fig3 bench: optimized-measure training at n={n} ==");
    for kind in MeasureKind::all() {
        microbench(&format!("train/{}", kind.as_str()), budget, || {
            let mut m = build_measure(kind, &cfg, None);
            m.fit(&ds);
            m.n()
        });
    }
}
