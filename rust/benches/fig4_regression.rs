//! Bench: Figure 4 — k-NN CP regression prediction latency:
//! Papadopoulos-2011 vs our optimization vs ICP.

use std::time::Duration;

use exact_cp::bench_harness::timing::microbench;
use exact_cp::data::{make_regression, RegressionSpec};
use exact_cp::regression::{
    IcpKnnRegressor, KnnRegressorOptimized, KnnRegressorStandard,
};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let budget = Duration::from_millis(if quick { 200 } else { 1500 });
    let n = if quick { 256 } else { 2048 };
    let k = 15;
    let spec = RegressionSpec {
        n_samples: n,
        n_features: 30,
        n_informative: 10,
        noise: 10.0,
    };
    let ds = make_regression(&spec, 1);
    let probe = make_regression(
        &RegressionSpec {
            n_samples: 2,
            ..spec
        },
        2,
    );
    let x = probe.row(0);
    println!("== fig4 bench: one regression region at n={n}, k={k} ==");

    let mut opt = KnnRegressorOptimized::new(k);
    opt.fit(&ds);
    microbench("optimized (ours)", budget, || {
        opt.predict_region(x, 0.1).intervals.len()
    });

    // Papadopoulos-2011 at reduced n (the O(n^2) side)
    let n_std = (n / 8).max(64);
    let ds_std = make_regression(
        &RegressionSpec {
            n_samples: n_std,
            ..spec
        },
        3,
    );
    let mut std_m = KnnRegressorStandard::new(k);
    std_m.fit(&ds_std);
    microbench(&format!("papadopoulos2011 (n={n_std})"), budget, || {
        std_m.predict_region(x, 0.1).intervals.len()
    });

    let mut icp = IcpKnnRegressor::new(k);
    icp.fit(&ds, n / 2);
    microbench("icp", budget, || icp.predict_interval(x, 0.1).0);
}
