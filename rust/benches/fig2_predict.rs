//! Bench: Figure 2 — per-prediction latency of standard vs optimized
//! full CP vs ICP at a fixed, meaningful n (end-to-end p-value
//! computation for one test point, both labels).
//!
//! Run: `cargo bench --bench fig2_predict` (pass `--quick` via
//! BENCH_QUICK=1 for a fast sanity pass).

use std::time::Duration;

use exact_cp::bench_harness::timing::microbench;
use exact_cp::config::{MeasureConfig, MeasureKind};
use exact_cp::coordinator::factory::{build_measure, build_standard_measure};
use exact_cp::cp::icp::Icp;
use exact_cp::cp::pvalue::p_value;
use exact_cp::data::{make_classification, ClassificationSpec};
use exact_cp::measures::{FeatureMap, IcpKde, IcpKnn, IcpLsSvm};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let budget = Duration::from_millis(if quick { 200 } else { 1500 });
    let n = if quick { 256 } else { 2048 };
    let cfg = MeasureConfig::default();

    let ds = make_classification(
        &ClassificationSpec {
            n_samples: n,
            ..Default::default()
        },
        1,
    );
    let probe = make_classification(
        &ClassificationSpec {
            n_samples: 4,
            ..Default::default()
        },
        2,
    );
    let x = probe.row(0);

    println!("== fig2 bench: one CP prediction (both labels) at n={n} ==");

    // optimized measures (the paper's contribution)
    for kind in [
        MeasureKind::SimplifiedKnn,
        MeasureKind::Knn,
        MeasureKind::Kde,
        MeasureKind::LsSvm,
    ] {
        let mut m = build_measure(kind, &cfg, None);
        m.fit(&ds);
        microbench(
            &format!("optimized/{}", kind.as_str()),
            budget,
            || {
                let mut acc = 0.0;
                for y in 0..2 {
                    acc += p_value(&m.scores(x, y));
                }
                acc
            },
        );
    }

    // standard baselines at a reduced n (they are the slow side)
    let n_std = (n / 8).max(64);
    let ds_std = make_classification(
        &ClassificationSpec {
            n_samples: n_std,
            ..Default::default()
        },
        3,
    );
    for kind in [MeasureKind::SimplifiedKnn, MeasureKind::Kde] {
        let mut m = build_standard_measure(kind, &cfg);
        m.fit(&ds_std);
        microbench(
            &format!("standard/{} (n={n_std})", kind.as_str()),
            budget,
            || {
                let mut acc = 0.0;
                for y in 0..2 {
                    acc += p_value(&m.scores(x, y));
                }
                acc
            },
        );
    }

    // ICP baselines
    let icp_knn = Icp::calibrate(IcpKnn::new(cfg.k, true), &ds, n / 2);
    microbench("icp/simplified-knn", budget, || {
        icp_knn.p_values(x).iter().sum::<f64>()
    });
    let icp_kde = Icp::calibrate(IcpKde::new(cfg.h), &ds, n / 2);
    microbench("icp/kde", budget, || {
        icp_kde.p_values(x).iter().sum::<f64>()
    });
    let icp_svm =
        Icp::calibrate(IcpLsSvm::new(cfg.rho, FeatureMap::Linear), &ds, n / 2);
    microbench("icp/lssvm", budget, || {
        icp_svm.p_values(x).iter().sum::<f64>()
    });
}
