//! Bench: decremental unlearn + repredict vs retrain-from-scratch.
//!
//! This is the acceptance gate for decremental regression serving
//! (ROADMAP "Regression serving gaps"): in the paper's online pattern —
//! remove a recent example, then serve the next prediction — the ridge
//! journal path (`RidgeCp::unlearn`, checkpoint + bounded replay) must
//! be at least 2x faster than refitting on the reduced set, at the
//! serving shape n=2000 training rows, p=16 features.
//!
//! Before timing, the bench asserts the exactness contract: after
//! `unlearn(idx)` (tail, head, and checkpoint-crossing indices) the
//! served coefficients are bit-identical to a fresh fit on the reduced
//! set, for ridge AND the optimized k-NN regressor.
//!
//! Results are written to `BENCH_online_unlearn.json`. Smoke mode
//! (`BENCH_QUICK=1` or a `--test` argument, used by CI) runs the
//! exactness asserts and emits the JSON but skips the 2x gate — shared
//! CI runners make wall-clock gates flaky.

use std::time::Duration;

use exact_cp::data::{make_regression, RegressionSpec};
use exact_cp::regression::{
    Coefficients, CpRegressor, KnnRegressorOptimized, RidgeCp,
};

const N: usize = 2000;
const P: usize = 16;
const RHO: f64 = 1.0;
const EPS: f64 = 0.1;

fn coefs_bits_eq(a: &Coefficients, b: &Coefficients) -> bool {
    a.1.to_bits() == b.1.to_bits()
        && a.2.to_bits() == b.2.to_bits()
        && a.0.len() == b.0.len()
        && a.0.iter().zip(&b.0).all(|(u, v)| {
            u.0.to_bits() == v.0.to_bits() && u.1.to_bits() == v.1.to_bits()
        })
}

fn main() {
    let smoke = std::env::var("BENCH_QUICK").is_ok()
        || std::env::args().any(|a| a == "--test");
    let budget = Duration::from_millis(if smoke { 150 } else { 1500 });

    let ds = make_regression(
        &RegressionSpec {
            n_samples: N,
            n_features: P,
            n_informative: 6,
            noise: 4.0,
        },
        42,
    );
    let probes = make_regression(
        &RegressionSpec {
            n_samples: 4,
            n_features: P,
            n_informative: 6,
            noise: 4.0,
        },
        43,
    );
    let xs: Vec<&[f64]> = (0..probes.n()).map(|i| probes.row(i)).collect();

    // ---- exactness contract (always enforced) -----------------------
    // tail, head, and checkpoint-boundary removals on a small copy (the
    // property suite covers this exhaustively; here it gates timing)
    {
        let small = make_regression(
            &RegressionSpec {
                n_samples: 200,
                n_features: P,
                n_informative: 6,
                noise: 4.0,
            },
            44,
        );
        let mut ridge = RidgeCp::new(RHO);
        let mut knn = KnnRegressorOptimized::new(5);
        CpRegressor::fit(&mut ridge, &small);
        CpRegressor::fit(&mut knn, &small);
        let mut reduced = small.clone();
        for idx in [199, 0, 127, 64, 50] {
            assert!(ridge.unlearn(idx), "ridge unlearn({idx})");
            assert!(knn.unlearn(idx), "knn unlearn({idx})");
            reduced.remove(idx);
            let mut fresh_r = RidgeCp::new(RHO);
            let mut fresh_k = KnnRegressorOptimized::new(5);
            CpRegressor::fit(&mut fresh_r, &reduced);
            CpRegressor::fit(&mut fresh_k, &reduced);
            for &x in &xs {
                assert!(
                    coefs_bits_eq(
                        &ridge.coefficients(x),
                        &fresh_r.coefficients(x)
                    ),
                    "ridge not bit-identical to refit after unlearn({idx})"
                );
                assert!(
                    coefs_bits_eq(
                        &knn.coefficients(x),
                        &fresh_k.coefficients(x)
                    ),
                    "knn not bit-identical to refit after unlearn({idx})"
                );
            }
        }
    }
    println!("exactness: unlearn == fresh refit for ridge + knn (bitwise)");

    // ---- timing -----------------------------------------------------
    // the online pattern: drop the most recent example, serve the next
    // region. The decremental path re-learns the row after predicting to
    // restore state for the next iteration (bit-exact round trip), so it
    // is charged for one learn MORE than the retrain path — conservative.
    println!("== online_unlearn: ridge n={N} p={P} ==");
    let (x_last, y_last) = (ds.row(N - 1).to_vec(), ds.y[N - 1]);
    let mut reduced = ds.clone();
    reduced.remove(N - 1);

    let mut live = RidgeCp::new(RHO);
    CpRegressor::fit(&mut live, &ds);
    let t_dec = exact_cp::bench_harness::timing::microbench(
        "unlearn + repredict (journal)",
        budget,
        || {
            assert!(live.unlearn(N - 1));
            let region = live.predict_region(xs[0], EPS);
            assert!(live.learn(&x_last, y_last));
            region.intervals.len()
        },
    );
    let t_retrain = exact_cp::bench_harness::timing::microbench(
        "retrain + repredict (from scratch)",
        budget,
        || {
            let mut fresh = RidgeCp::new(RHO);
            CpRegressor::fit(&mut fresh, &reduced);
            fresh.predict_region(xs[0], EPS).intervals.len()
        },
    );
    let speedup = t_retrain / t_dec;
    println!("online_unlearn: decremental speedup {speedup:.2}x");

    let json = format!(
        "{{\n  \"bench\": \"online_unlearn\",\n  \"n\": {N},\n  \
         \"p\": {P},\n  \"rho\": {RHO},\n  \
         \"decremental_s\": {t_dec:.9},\n  \
         \"retrain_s\": {t_retrain:.9},\n  \"speedup\": {speedup:.4},\n  \
         \"smoke\": {smoke}\n}}\n"
    );
    std::fs::write("BENCH_online_unlearn.json", &json)
        .expect("writing BENCH_online_unlearn.json");
    println!("wrote BENCH_online_unlearn.json");

    if !smoke {
        assert!(
            speedup >= 2.0,
            "decremental path must be >= 2x retrain, got {speedup:.2}x"
        );
    }
}
