//! Bench: Table 3 (App. H) — sequential vs thread-pool-parallel CP over
//! a test batch, optimized Simplified k-NN.

use std::time::Duration;

use exact_cp::bench_harness::timing::{microbench, parallel_map};
use exact_cp::config::{MeasureConfig, MeasureKind};
use exact_cp::coordinator::factory::build_measure;
use exact_cp::cp::pvalue::p_value;
use exact_cp::data::{make_classification, ClassificationSpec};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let budget = Duration::from_millis(if quick { 200 } else { 1500 });
    let n = if quick { 256 } else { 1000 };
    let n_test = 16;
    let cfg = MeasureConfig::default();
    let all = make_classification(
        &ClassificationSpec {
            n_samples: n + n_test,
            ..Default::default()
        },
        1,
    );
    let mut rng = exact_cp::data::Rng::seed_from(2);
    let (train, test) = all.split(n, &mut rng);
    let mut m = build_measure(MeasureKind::SimplifiedKnn, &cfg, None);
    m.fit(&train);
    let m = &m;
    println!(
        "== table3 bench: batch of {n_test} predictions at n={n} \
         (cores available: {}) ==",
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    );
    let work = |i: usize| {
        let mut acc = 0.0;
        for y in 0..2 {
            acc += p_value(&m.scores(test.row(i), y));
        }
        acc
    };
    microbench("sequential", budget, || {
        (0..n_test).map(work).sum::<f64>()
    });
    for threads in [2usize, 4, 8] {
        microbench(&format!("parallel x{threads}"), budget, || {
            parallel_map(n_test, threads, work).into_iter().sum::<f64>()
        });
    }
    // The batched engine: the whole test set through ONE scores_batch
    // call (row per object shared across labels), then chunked across a
    // thread pool — the serving coordinator's configuration.
    let xs: Vec<&[f64]> = (0..n_test).map(|i| test.row(i)).collect();
    microbench("batched (one scores_batch)", budget, || {
        m.scores_batch(&xs, &[0, 1])
            .iter()
            .map(p_value)
            .sum::<f64>()
    });
    for threads in [2usize, 4] {
        microbench(&format!("batched parallel x{threads}"), budget, || {
            let chunk = (n_test + threads - 1) / threads;
            parallel_map(threads, threads, |t| {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(n_test);
                m.scores_batch(&xs[lo..hi], &[0, 1])
                    .iter()
                    .map(p_value)
                    .sum::<f64>()
            })
            .into_iter()
            .sum::<f64>()
        });
    }
}
