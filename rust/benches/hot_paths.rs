//! Bench: micro-level hot paths — the §Perf optimization targets.
//!
//! * distance row (native vs PJRT/Pallas)
//! * KBest insert + sum_with (the O(1) update of §3.1)
//! * LS-SVM virtual decrement (w_without)
//! * p-value counting
//! * full optimized score vector (one scores() call)

use std::time::Duration;

use exact_cp::bench_harness::timing::microbench;
use exact_cp::cp::measure::{CpMeasure, Scores};
use exact_cp::cp::pvalue::p_value;
use exact_cp::data::{make_classification, ClassificationSpec, Rng};
use exact_cp::linalg::engine::{DistEngine, NativeEngine};
use exact_cp::linalg::select::KBest;
use exact_cp::measures::knn::KnnOptimized;
use exact_cp::measures::lssvm::{FeatureMap, LsSvmModel};
use exact_cp::measures::LsSvmOptimized;
use exact_cp::runtime::PjrtRuntime;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let budget = Duration::from_millis(if quick { 150 } else { 1000 });
    let n = 2048usize;
    let p = 30usize;
    let mut rng = Rng::seed_from(1);
    let rows: Vec<f64> = (0..n * p).map(|_| rng.normal()).collect();
    let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    let mut out = vec![0.0; n];

    println!("== hot-path micro benches (n={n}, p={p}) ==");

    microbench("dist_row native", budget, || {
        NativeEngine.dist_row_sq(&x, &rows, p, &mut out);
        out[0]
    });

    if let Ok(rt) = PjrtRuntime::open("artifacts") {
        // warm the executable cache outside the timed region
        let _ = rt.dist_row_sq_f32(&x, &rows, p);
        microbench("dist_row pjrt/pallas", budget, || {
            rt.dist_row_sq_f32(&x, &rows, p).unwrap()[0]
        });
        let alpha = vec![1.0; n];
        let delta = vec![0.5; n];
        let same = vec![1.0; n];
        let _ = rt.knn_update_f32(&x, &rows, p, &alpha, &delta, &same);
        microbench("knn_update fused pjrt", budget, || {
            rt.knn_update_f32(&x, &rows, p, &alpha, &delta, &same)
                .unwrap()[0]
        });
    } else {
        println!("(artifacts missing — skipping PJRT rows)");
    }

    // KBest update path
    let mut kb = KBest::new(15);
    for _ in 0..200 {
        kb.insert(rng.f64());
    }
    microbench("kbest sum_with (O(1) update)", budget, || {
        kb.sum_with(0.3)
    });

    // p-value counting over a big score vector
    let scores = Scores {
        train: (0..n).map(|_| rng.f64()).collect(),
        test: 0.5,
    };
    microbench("p_value count (n=2048)", budget, || p_value(&scores));

    // LS-SVM virtual decrement
    let q = 30;
    let phis: Vec<f64> = (0..64 * q).map(|_| rng.normal()).collect();
    let phi_mat = exact_cp::linalg::Mat {
        data: phis,
        rows: 64,
        cols: q,
    };
    let ys: Vec<f64> = (0..64)
        .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
        .collect();
    let model = LsSvmModel::train(&phi_mat, &ys, 1.0);
    let mut w_buf = Vec::with_capacity(q);
    microbench("lssvm w_without (O(q^2))", budget, || {
        model.w_without(phi_mat.row(3), ys[3], &mut w_buf);
        w_buf[0]
    });

    // end-to-end optimized scores() calls
    let ds = make_classification(
        &ClassificationSpec {
            n_samples: n,
            ..Default::default()
        },
        5,
    );
    let mut knn = KnnOptimized::new(15, true);
    knn.fit(&ds);
    microbench("scores(): simplified-knn opt n=2048", budget, || {
        knn.scores(&x, 0).test
    });
    let mut svm = LsSvmOptimized::new(1.0, FeatureMap::Linear);
    svm.fit(&ds);
    microbench("scores(): lssvm opt n=2048", budget, || {
        svm.scores(&x, 0).test
    });

    // batched scoring: 8 objects x 2 labels in one scores_batch call —
    // the distance/kernel row per object is shared across labels
    let probe: Vec<Vec<f64>> = (0..8)
        .map(|_| (0..ds.p).map(|_| rng.normal()).collect())
        .collect();
    let xs: Vec<&[f64]> = probe.iter().map(|v| v.as_slice()).collect();
    microbench("scores_batch(): sknn 8x2 pairs", budget, || {
        knn.scores_batch(&xs, &[0, 1]).len()
    });
    microbench("scores_batch(): lssvm 8x2 pairs", budget, || {
        svm.scores_batch(&xs, &[0, 1]).len()
    });
}
