// Fixture: LOCK001 — unsafe block with no safety rationale comment.

pub fn first(xs: &[f64]) -> f64 {
    unsafe { *xs.get_unchecked(0) }
}
