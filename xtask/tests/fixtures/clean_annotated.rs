// Fixture: every rule exercised with a correct annotation — the lint
// must report nothing here (linted as rust/src/cp/fixture.rs).

use std::sync::{Mutex, RwLock};

pub struct S {
    registry: RwLock<Vec<f64>>,
    cache: Mutex<Vec<f64>>,
}

impl S {
    pub fn ordered(&self) -> std::thread::JoinHandle<()> {
        // THREADS: fixture worker joined by the caller.
        // LOCK-ORDER: coordinator.registry — outer lock first.
        let a = self.registry.read().unwrap();
        // LOCK-ORDER: runtime.exec_cache — inner lock second.
        let b = self.cache.lock().unwrap();
        drop((a, b));
        std::thread::spawn(|| {})
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    // EXACT-ALLOW: EXACT001 fixture — fixed reduction order is the spec.
    let s: f64 = xs.iter().sum();
    s / xs.len() as f64
}

pub fn head(xs: &[f64]) -> f64 {
    // SAFETY: caller guarantees xs is non-empty.
    unsafe { *xs.get_unchecked(0) }
}
