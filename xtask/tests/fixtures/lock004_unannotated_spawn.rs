// Fixture: LOCK004 — thread spawn in a function with no THREADS: note.

pub fn background() -> std::thread::JoinHandle<()> {
    std::thread::spawn(|| {})
}
