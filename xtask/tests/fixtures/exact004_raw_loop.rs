// Fixture: EXACT004 — raw accumulation loop in linalg outside a
// blessed kernel (linted as rust/src/linalg/fixture.rs).

pub fn my_dot(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}
