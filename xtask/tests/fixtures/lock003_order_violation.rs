// Fixture: LOCK003 — both acquisitions are annotated with valid names,
// but the second is ranked *above* the first in LOCK_ORDER.

use std::sync::{Mutex, RwLock};

pub struct S {
    cache: Mutex<Vec<u8>>,
    registry: RwLock<Vec<u8>>,
}

impl S {
    pub fn backwards(&self) -> usize {
        // LOCK-ORDER: runtime.exec_cache — taken first (wrongly).
        let a = self.cache.lock().unwrap();
        // LOCK-ORDER: coordinator.registry — outer lock taken second.
        let b = self.registry.read().unwrap();
        a.len() + b.len()
    }
}
