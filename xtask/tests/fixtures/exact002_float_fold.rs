// Fixture: EXACT002 — fold with a float accumulator.

pub fn total(xs: &[f64]) -> f64 {
    xs.iter().fold(0.0f64, |acc, x| acc + x)
}
