// Fixture: EXACT003 — FMA contraction in a critical module.

pub fn axpy(a: f64, x: f64, y: f64) -> f64 {
    a.mul_add(x, y)
}
