// Fixture: LOCK002 — lock acquisition without a LOCK-ORDER annotation.

use std::sync::Mutex;

pub fn drain(q: &Mutex<Vec<u8>>) -> Vec<u8> {
    let mut g = q.lock().unwrap();
    std::mem::take(&mut *g)
}
