// Fixture: EXACT001 — iterator sum over floats in a critical module.
// Linted with the synthetic path rust/src/cp/fixture.rs.

pub fn mean(xs: &[f64]) -> f64 {
    let s: f64 = xs.iter().sum();
    s / xs.len() as f64
}
