//! Each bad fixture must fire exactly one diagnostic with its stable
//! code; the clean fixture must fire none. Fixtures are linted under
//! synthetic `rust/src/...` paths so the critical-module and
//! blessed-kernel tables apply exactly as they do on the real tree.

use std::fs;
use std::path::PathBuf;

fn fixture(name: &str) -> String {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {p:?}: {e}"))
}

/// Lint `fixture_file` as if it lived at `rel` and assert exactly one
/// finding with `code`.
fn assert_one(fixture_file: &str, rel: &str, code: &str) {
    let src = fixture(fixture_file);
    let found = xtask::lint_file(rel, &src);
    assert_eq!(
        found.len(),
        1,
        "{fixture_file}: expected exactly one finding, got {:?}",
        found.iter().map(|d| d.human()).collect::<Vec<_>>()
    );
    assert_eq!(found[0].code, code, "{fixture_file}: {}", found[0].human());
}

#[test]
fn exact001_iterator_float_sum() {
    assert_one(
        "exact001_float_sum.rs",
        "rust/src/cp/fixture.rs",
        "EXACT001",
    );
}

#[test]
fn exact002_float_fold() {
    assert_one(
        "exact002_float_fold.rs",
        "rust/src/measures/fixture.rs",
        "EXACT002",
    );
}

#[test]
fn exact003_mul_add() {
    assert_one(
        "exact003_mul_add.rs",
        "rust/src/regression/fixture.rs",
        "EXACT003",
    );
}

#[test]
fn exact004_raw_linalg_loop() {
    assert_one(
        "exact004_raw_loop.rs",
        "rust/src/linalg/fixture.rs",
        "EXACT004",
    );
}

#[test]
fn lock001_undocumented_unsafe() {
    assert_one(
        "lock001_missing_safety.rs",
        "rust/src/runtime/fixture.rs",
        "LOCK001",
    );
}

#[test]
fn lock002_missing_lock_order() {
    assert_one(
        "lock002_missing_lock_order.rs",
        "rust/src/coordinator/fixture.rs",
        "LOCK002",
    );
}

#[test]
fn lock003_rank_violation() {
    assert_one(
        "lock003_order_violation.rs",
        "rust/src/coordinator/fixture.rs",
        "LOCK003",
    );
}

#[test]
fn lock004_unannotated_spawn() {
    assert_one(
        "lock004_unannotated_spawn.rs",
        "rust/src/coordinator/fixture.rs",
        "LOCK004",
    );
}

#[test]
fn clean_fixture_is_clean() {
    let src = fixture("clean_annotated.rs");
    let found = xtask::lint_file("rust/src/cp/fixture.rs", &src);
    assert!(
        found.is_empty(),
        "clean fixture fired: {:?}",
        found.iter().map(|d| d.human()).collect::<Vec<_>>()
    );
}

#[test]
fn critical_paths_are_position_independent() {
    // the same bad source is clean outside the critical modules but
    // flagged inside every one of them
    let src = fixture("exact001_float_sum.rs");
    assert!(xtask::lint_file("rust/src/data/fixture.rs", &src).is_empty());
    for dir in xtask::exactness::CRITICAL_DIRS {
        let rel = format!("rust/{dir}fixture.rs");
        assert_eq!(xtask::lint_file(&rel, &src).len(), 1, "{rel}");
    }
}

#[test]
fn exact_allow_requires_matching_code_and_rationale() {
    let base = "pub fn m(xs: &[f64]) -> f64 {\n    let s: f64 = xs.iter().sum();\n    s\n}\n";
    let rel = "rust/src/cp/fixture.rs";
    assert_eq!(xtask::lint_file(rel, base).len(), 1);
    // wrong code does not silence the finding
    let wrong = base.replace(
        "    let s:",
        "    // EXACT-ALLOW: EXACT002 wrong code\n    let s:",
    );
    assert_eq!(xtask::lint_file(rel, &wrong).len(), 1);
    // bare code with no rationale does not count either
    let bare = base.replace(
        "    let s:",
        "    // EXACT-ALLOW: EXACT001\n    let s:",
    );
    assert_eq!(xtask::lint_file(rel, &bare).len(), 1);
    // right code + rationale silences it
    let right = base.replace(
        "    let s:",
        "    // EXACT-ALLOW: EXACT001 order is the spec here\n    let s:",
    );
    assert!(xtask::lint_file(rel, &right).is_empty());
}

#[test]
fn real_tree_is_clean_when_present() {
    // when run from the workspace (CI does), the whole tree must lint
    // clean; skip quietly if the layout is not there (sandboxed runs)
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
    if !root.join("rust/src").is_dir() {
        return;
    }
    let found = xtask::lint_tree(&root).expect("walk rust/src");
    assert!(
        found.is_empty(),
        "real tree has lint findings:\n{}",
        found
            .iter()
            .map(|d| d.human())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
