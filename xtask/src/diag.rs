//! Lint diagnostics: stable codes, human rendering, and GitHub
//! workflow-annotation rendering.
//!
//! Codes are part of the contract (fixtures and EXACTNESS.md refer to
//! them by name) — never renumber, only append.

/// One lint finding at a file/line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Stable code, e.g. `EXACT001` or `LOCK002`.
    pub code: &'static str,
    /// Workspace-relative path with forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub msg: String,
}

/// Exactness lint: reassociation-hazard iterator reduction
/// (`.sum()` / `.product()` over a float iterator chain).
pub const EXACT001: &str = "EXACT001";
/// Exactness lint: `fold` / `reduce` with a float accumulator.
pub const EXACT002: &str = "EXACT002";
/// Exactness lint: `mul_add` (FMA contraction changes results bitwise).
pub const EXACT003: &str = "EXACT003";
/// Exactness lint: compound-assignment accumulation in `linalg/`
/// outside a blessed kernel function.
pub const EXACT004: &str = "EXACT004";
/// Concurrency lint: `unsafe` site without a `// SAFETY:` rationale.
pub const LOCK001: &str = "LOCK001";
/// Concurrency lint: lock acquisition without a valid
/// `// LOCK-ORDER: <name>` annotation.
pub const LOCK002: &str = "LOCK002";
/// Concurrency lint: annotated acquisitions violate the declared
/// lock order within one function.
pub const LOCK003: &str = "LOCK003";
/// Concurrency lint: thread spawn site in a function without a
/// `// THREADS:` discipline note.
pub const LOCK004: &str = "LOCK004";

impl Diagnostic {
    pub fn new(code: &'static str, file: &str, line: usize, msg: String) -> Self {
        Diagnostic {
            code,
            file: file.to_string(),
            line,
            msg,
        }
    }

    /// `path:line: CODE message` — the terminal rendering.
    pub fn human(&self) -> String {
        format!("{}:{}: {} {}", self.file, self.line, self.code, self.msg)
    }

    /// GitHub Actions workflow-command rendering (shows up as an
    /// inline annotation on the PR diff).
    pub fn github(&self) -> String {
        format!(
            "::error file={},line={},title={}::{}",
            self.file, self.line, self.code, self.msg
        )
    }
}
