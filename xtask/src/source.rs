//! A comment/string-aware line model of one Rust source file.
//!
//! This is deliberately *not* a parser: the offline build environment
//! rules out `syn`, and the lint rules only need (a) code with comment,
//! string and char-literal contents stripped, (b) the comment text per
//! line (annotations live there), (c) which lines sit inside
//! `#[cfg(test)]` items, and (d) which named `fn` encloses each line.
//! A character-level state machine over the raw text provides all four
//! with no dependencies.

/// Span of one named function (0-based line numbers, inclusive).
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub start: usize,
    pub end: usize,
}

/// The per-line model the lint passes operate on.
#[derive(Debug)]
pub struct SourceModel {
    /// Code text per line: comments removed, string/char literal
    /// contents dropped (an empty `""` marks where a string was).
    pub code: Vec<String>,
    /// Comment text per line (line + block comments concatenated).
    pub comments: Vec<String>,
    /// True for lines inside a `#[cfg(test)]` item.
    pub in_test: Vec<bool>,
    /// Innermost enclosing named fn per line (index into `fns`).
    pub fn_of: Vec<Option<usize>>,
    pub fns: Vec<FnSpan>,
    /// All code lines joined with `\n` (for cross-line token search).
    pub joined: String,
    /// Byte offset of each line's start within `joined`.
    pub line_offsets: Vec<usize>,
}

#[derive(Clone, Copy)]
enum Mode {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    CharLit,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// If `chars[i]` begins a raw (byte) string literal (`r"`, `r#"`,
/// `br"`, ...), return `(hash_count, index_after_opening_quote)`.
fn raw_str_start(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        j += 1;
        hashes += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Strip comments and literal contents; returns (code, comments) per
/// line. Both vectors have identical length (one entry per line).
fn strip(src: &str) -> (Vec<String>, Vec<String>) {
    let chars: Vec<char> = src.chars().collect();
    let mut code = vec![String::new()];
    let mut comments = vec![String::new()];
    let mut mode = Mode::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            code.push(String::new());
            comments.push(String::new());
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Code;
            }
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    code.last_mut().unwrap().push_str("\"\"");
                    mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b')
                    && (i == 0 || !is_ident(chars[i - 1]))
                {
                    if let Some((hashes, after)) = raw_str_start(&chars, i) {
                        code.last_mut().unwrap().push_str("\"\"");
                        mode = Mode::RawStr(hashes);
                        i = after;
                    } else if c == 'b' && next == Some('"') {
                        code.last_mut().unwrap().push_str("\"\"");
                        mode = Mode::Str;
                        i += 2;
                    } else if c == 'b' && next == Some('\'') {
                        mode = Mode::CharLit;
                        i += 2;
                    } else {
                        code.last_mut().unwrap().push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // char literal vs lifetime: 'x' or '\... is a char
                    // literal; anything else ('a in generics, 'static)
                    // is a lifetime and stays in the code stream.
                    let n2 = chars.get(i + 2).copied();
                    if next == Some('\\') || n2 == Some('\'') {
                        mode = Mode::CharLit;
                        i += 1;
                    } else {
                        code.last_mut().unwrap().push(c);
                        i += 1;
                    }
                } else {
                    code.last_mut().unwrap().push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                comments.last_mut().unwrap().push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    mode = if depth == 1 {
                        Mode::Code
                    } else {
                        Mode::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else {
                    comments.last_mut().unwrap().push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' {
                    let mut k = 0u32;
                    while k < hashes
                        && chars.get(i + 1 + k as usize) == Some(&'#')
                    {
                        k += 1;
                    }
                    if k == hashes {
                        mode = Mode::Code;
                        i += 1 + hashes as usize;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
            Mode::CharLit => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    (code, comments)
}

impl SourceModel {
    pub fn build(src: &str) -> SourceModel {
        let (code, comments) = strip(src);
        let n = code.len();
        let mut in_test = vec![false; n];
        let mut fns: Vec<FnSpan> = Vec::new();
        let mut fn_stack: Vec<(usize, i32)> = Vec::new();
        let mut pending_fn: Option<usize> = None;
        let mut pending_cfg_test = false;
        let mut test_open_depth: Option<i32> = None;
        let mut depth: i32 = 0;
        // paren/bracket nesting — a `;` inside `[f64; 4]` or a default
        // type parameter must not cancel a pending fn signature
        let mut sig_depth: i32 = 0;

        for (li, line) in code.iter().enumerate() {
            // a nested #[cfg(test)] inside an already-open test region
            // must not restart (and later prematurely close) the region
            if line.contains("#[cfg(test)]") && test_open_depth.is_none() {
                pending_cfg_test = true;
            }
            let mut line_is_test = test_open_depth.is_some();
            let bytes: Vec<char> = line.chars().collect();
            let mut k = 0;
            while k < bytes.len() {
                let c = bytes[k];
                if c == '{' {
                    if pending_cfg_test {
                        pending_cfg_test = false;
                        test_open_depth = Some(depth);
                        line_is_test = true;
                    }
                    if let Some(fi) = pending_fn.take() {
                        fn_stack.push((fi, depth));
                    }
                    depth += 1;
                    k += 1;
                } else if c == '}' {
                    depth -= 1;
                    if test_open_depth == Some(depth) {
                        test_open_depth = None;
                    }
                    while let Some(&(fi, fd)) = fn_stack.last() {
                        if depth == fd {
                            fns[fi].end = li;
                            fn_stack.pop();
                        } else {
                            break;
                        }
                    }
                    k += 1;
                } else if c == '(' || c == '[' {
                    sig_depth += 1;
                    k += 1;
                } else if c == ')' || c == ']' {
                    sig_depth = (sig_depth - 1).max(0);
                    k += 1;
                } else if c == ';' {
                    // `#[cfg(test)] use ...;` or a bodyless trait decl —
                    // but only at nesting depth 0 (`[f64; 4]` is not a
                    // statement end)
                    if sig_depth == 0 {
                        if test_open_depth.is_none() {
                            pending_cfg_test = false;
                        }
                        pending_fn = None;
                    }
                    k += 1;
                } else if is_ident(c) && !c.is_ascii_digit() {
                    let s = k;
                    while k < bytes.len() && is_ident(bytes[k]) {
                        k += 1;
                    }
                    let word: String = bytes[s..k].iter().collect();
                    if word == "fn" {
                        let mut k2 = k;
                        while k2 < bytes.len() && bytes[k2].is_whitespace() {
                            k2 += 1;
                        }
                        let s2 = k2;
                        while k2 < bytes.len() && is_ident(bytes[k2]) {
                            k2 += 1;
                        }
                        if k2 > s2 {
                            let name: String = bytes[s2..k2].iter().collect();
                            fns.push(FnSpan {
                                name,
                                start: li,
                                end: li,
                            });
                            pending_fn = Some(fns.len() - 1);
                            k = k2;
                        }
                    }
                } else {
                    k += 1;
                }
            }
            in_test[li] = line_is_test || test_open_depth.is_some();
        }
        // unclosed functions (EOF) extend to the last line
        for &(fi, _) in &fn_stack {
            fns[fi].end = n.saturating_sub(1);
        }

        let mut fn_of = vec![None; n];
        for (idx, f) in fns.iter().enumerate() {
            for slot in fn_of.iter_mut().take(f.end + 1).skip(f.start) {
                *slot = Some(idx);
            }
        }

        let mut joined = String::new();
        let mut line_offsets = Vec::with_capacity(n);
        for l in &code {
            line_offsets.push(joined.len());
            joined.push_str(l);
            joined.push('\n');
        }

        SourceModel {
            code,
            comments,
            in_test,
            fn_of,
            fns,
            joined,
            line_offsets,
        }
    }

    /// Map a byte offset in `joined` to its 0-based line number.
    pub fn line_of(&self, pos: usize) -> usize {
        match self.line_offsets.binary_search(&pos) {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        }
    }

    /// Name of the innermost function enclosing `line`, if any.
    pub fn fn_name(&self, line: usize) -> Option<&str> {
        self.fn_of
            .get(line)
            .copied()
            .flatten()
            .map(|i| self.fns[i].name.as_str())
    }

    /// True if a comment containing `marker` appears on `line` itself
    /// or within the `window` lines directly above it.
    pub fn comment_near(&self, line: usize, window: usize, marker: &str) -> bool {
        let lo = line.saturating_sub(window);
        (lo..=line)
            .any(|l| self.comments.get(l).is_some_and(|c| c.contains(marker)))
    }

    /// The text after the first occurrence of `marker` in the comments
    /// on `line` or the `window` lines above (nearest-last wins).
    pub fn annotation_near(&self, line: usize, window: usize, marker: &str) -> Option<String> {
        let lo = line.saturating_sub(window);
        let mut found = None;
        for l in lo..=line {
            if let Some(c) = self.comments.get(l) {
                if let Some(p) = c.find(marker) {
                    found = Some(c[p + marker.len()..].trim().to_string());
                }
            }
        }
        found
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let m = SourceModel::build(
            "let a = \"x.sum()\"; // c.sum()\nlet b = 1; /* y\n.sum() */ let c = 2;\n",
        );
        assert!(!m.joined.contains("sum"));
        assert!(m.comments[0].contains("c.sum()"));
        assert!(m.comments[1].contains('y'));
        assert!(m.code[0].contains("let a"));
        assert!(m.code[2].contains("let c"));
    }

    #[test]
    fn raw_strings_and_chars() {
        let m = SourceModel::build(concat!(
            "let s = r#\"a \" .sum() \"#;\nlet c = '\\'';\n",
            "let l: &'static str = \"\";\nlet d = 'x';\n",
        ));
        assert!(!m.joined.contains("sum"));
        assert!(!m.joined.contains('x'));
        assert!(m.code[2].contains("'static"));
    }

    #[test]
    fn cfg_test_region_detected() {
        let src = concat!(
            "fn real() { a(); }\n#[cfg(test)]\nmod tests {\n",
            "    fn t() { b(); }\n}\nfn after() {}\n",
        );
        let m = SourceModel::build(src);
        assert!(!m.in_test[0]);
        assert!(m.in_test[2]);
        assert!(m.in_test[3]);
        assert!(m.in_test[4]);
        assert!(!m.in_test[5]);
    }

    #[test]
    fn fn_spans_nested() {
        let src = concat!(
            "fn outer() {\n    let c = |x: i32| {\n        x\n    };\n",
            "    inner_call();\n}\nfn second() {\n}\n",
        );
        let m = SourceModel::build(src);
        assert_eq!(m.fn_name(0), Some("outer"));
        assert_eq!(m.fn_name(2), Some("outer"));
        assert_eq!(m.fn_name(4), Some("outer"));
        assert_eq!(m.fn_name(6), Some("second"));
    }

    #[test]
    fn trait_decl_without_body_is_not_a_span() {
        let src = "trait T {\n    fn decl(&self) -> usize;\n}\nfn body() { x(); }\n";
        let m = SourceModel::build(src);
        assert_eq!(m.fn_name(3), Some("body"));
        // the bodyless decl never opens a span over following lines
        assert_eq!(m.fn_name(2), None);
    }

    #[test]
    fn array_return_type_semicolon_keeps_fn_span() {
        // the `;` in `[f64; 4]` must not cancel the pending signature
        let src = "fn quad(a: &[f64]) -> [f64; 4] {\n    let mut acc = [0.0; 4];\n    acc\n}\n";
        let m = SourceModel::build(src);
        assert_eq!(m.fn_name(1), Some("quad"));
        assert_eq!(m.fn_name(2), Some("quad"));
    }

    #[test]
    fn annotation_window() {
        let src = "// LOCK-ORDER: batcher.queue — drain path\nlet x = 1;\nlet g = q.lock();\n";
        let m = SourceModel::build(src);
        assert!(m.comment_near(2, 3, "LOCK-ORDER:"));
        let a = m.annotation_near(2, 3, "LOCK-ORDER:").unwrap();
        assert!(a.starts_with("batcher.queue"));
        assert!(!m.comment_near(2, 3, "SAFETY:"));
    }
}
