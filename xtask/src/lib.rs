//! In-tree static analysis for the exactness and concurrency contracts.
//!
//! Two passes run over every file in `rust/src/**`:
//!
//! - [`exactness`] — flags float-reassociation hazards (EXACT001–004)
//!   in the exactness-critical modules (`linalg/`, `measures/`,
//!   `regression/`, `cp/`);
//! - [`concurrency`] — inventories `unsafe` sites, lock acquisitions
//!   and thread spawns and requires structured `SAFETY:` /
//!   `LOCK-ORDER:` / `THREADS:` annotations (LOCK001–004), validated
//!   against the declared [`concurrency::LOCK_ORDER`] table.
//!
//! See EXACTNESS.md at the workspace root for the contract, the
//! annotation grammar, and how to extend the blessed-kernel table.
//! Entry point: `cargo run -p xtask -- lint`.

pub mod concurrency;
pub mod diag;
pub mod exactness;
pub mod source;

use std::fs;
use std::path::Path;

use diag::Diagnostic;
use source::SourceModel;

/// Lint one file's source text. `rel` is the workspace-relative path
/// with forward slashes (it drives the critical-module and blessed
/// tables, so fixtures pass synthetic paths like
/// `rust/src/linalg/fixture.rs`).
pub fn lint_file(rel: &str, src: &str) -> Vec<Diagnostic> {
    let model = SourceModel::build(src);
    let mut out = exactness::check(rel, &model);
    out.extend(concurrency::check(rel, &model));
    out
}

fn walk(dir: &Path, files: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, files)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            files.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `<root>/rust/src`, returning findings
/// sorted by (file, line, code). `root` is the workspace root.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Diagnostic>> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    walk(&src_root, &mut files)?;
    let mut out = Vec::new();
    for p in &files {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(p)?;
        out.extend(lint_file(&rel, &src));
    }
    out.sort_by(|a, b| (&a.file, a.line, a.code).cmp(&(&b.file, b.line, b.code)));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_annotated_source_has_no_findings() {
        let src = "\
// LOCK-ORDER: batcher.queue — single lock, drain path
fn drain(q: &std::sync::Mutex<Vec<f64>>) -> Vec<f64> {
    let mut g = q.lock().unwrap();
    std::mem::take(&mut *g)
}
";
        assert!(lint_file("rust/src/coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn non_critical_file_skips_exactness() {
        let src = "fn f(xs: &[f64]) -> f64 { xs.iter().sum() }\n";
        assert!(lint_file("rust/src/bench_harness/x.rs", src).is_empty());
        assert_eq!(lint_file("rust/src/cp/x.rs", src).len(), 1);
    }
}
