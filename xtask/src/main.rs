//! `cargo run -p xtask -- lint [--github] [--root <dir>]`
//!
//! Exit codes: 0 clean, 1 findings, 2 usage / IO error.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "\
usage: cargo run -p xtask -- lint [--github] [--root <dir>]

  lint        run the exactness + concurrency lint over rust/src/**
  --github    also emit ::error workflow commands (implied when the
              GITHUB_ACTIONS env var is set)
  --root DIR  workspace root (default: current directory)
";

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let Some(cmd) = args.next() else {
        eprint!("{USAGE}");
        return ExitCode::from(2);
    };
    if cmd != "lint" {
        eprintln!("unknown command {cmd:?}\n");
        eprint!("{USAGE}");
        return ExitCode::from(2);
    }
    let mut github = std::env::var_os("GITHUB_ACTIONS").is_some();
    let mut root = PathBuf::from(".");
    while let Some(a) = args.next() {
        match a.as_str() {
            "--github" => github = true,
            "--root" => match args.next() {
                Some(d) => root = PathBuf::from(d),
                None => {
                    eprintln!("--root needs a directory\n");
                    eprint!("{USAGE}");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("unknown flag {other:?}\n");
                eprint!("{USAGE}");
                return ExitCode::from(2);
            }
        }
    }

    let findings = match xtask::lint_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("xtask lint: cannot read tree under {root:?}: {e}");
            return ExitCode::from(2);
        }
    };

    if findings.is_empty() {
        println!("xtask lint: clean (exactness + concurrency)");
        return ExitCode::SUCCESS;
    }
    for d in &findings {
        eprintln!("{}", d.human());
        if github {
            println!("{}", d.github());
        }
    }
    eprintln!("xtask lint: {} finding(s)", findings.len());
    ExitCode::from(1)
}
