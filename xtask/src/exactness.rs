//! Exactness lint (EXACT001–EXACT004).
//!
//! The paper's contract is that every fast path is *bit-identical* to
//! the naive path, which forbids reassociating float reductions. Inside
//! the exactness-critical modules this pass flags:
//!
//! - `EXACT001` — `.sum()` / `.product()` at the end of an iterator
//!   adapter chain over floats (iterator reductions are the easiest
//!   place to silently reassociate during a refactor);
//! - `EXACT002` — `fold` / `reduce` with a float accumulator;
//! - `EXACT003` — any `mul_add` (FMA contraction is not the same bit
//!   pattern as mul-then-add);
//! - `EXACT004` — compound-assignment accumulation (`+=` etc.) inside
//!   `linalg/` but outside a blessed kernel function: new float loops
//!   must route through the blessed kernels, not reimplement them.
//!
//! Escape hatches, in order of preference (see EXACTNESS.md):
//! 1. put the reduction inside a blessed kernel ([`BLESSED`]);
//! 2. annotate the site: `// EXACT-ALLOW: EXACT001 <why it is exact>`.
//!
//! Heuristics, stated honestly: a lexer cannot type-check. A reduction
//! is treated as float unless the statement carries an integer marker
//! (`usize`, `.len()`, `to_bits`, ...) and no float marker — unknown
//! types fail closed (they get flagged and need an annotation).

use crate::diag::{Diagnostic, EXACT001, EXACT002, EXACT003, EXACT004};
use crate::source::SourceModel;

/// Modules under `rust/src/` bound by the exactness contract.
pub const CRITICAL_DIRS: [&str; 4] =
    ["src/linalg/", "src/measures/", "src/regression/", "src/cp/"];

/// Blessed kernel functions, per file suffix: the only places allowed
/// to contain raw float accumulation. Adding an entry is a reviewed,
/// documented act — see EXACTNESS.md before touching this table.
pub const BLESSED: &[(&str, &[&str])] = &[
    (
        "linalg/mod.rs",
        &[
            "matvec",
            "tmatvec",
            "matmul",
            "gram",
            "gram_accum_row",
            "tmatvec_accum_row",
            "add_diag",
            "rank1_update",
            "dot",
            "dot_matrix",
            "cholesky",
            "chol_solve",
            "spd_inverse",
        ],
    ),
    (
        "linalg/distance.rs",
        &[
            "sq_dist",
            "sq_dist_x4",
            "dist_row_sq_into",
            "dist_matrix_sq_into",
            "pairwise_sq",
        ],
    ),
    (
        "linalg/select.rs",
        &["k_smallest", "k_smallest_by", "from_slice", "insert"],
    ),
];

const ADAPTERS: [&str; 16] = [
    ".iter()",
    ".iter_mut()",
    ".into_iter()",
    ".map(",
    ".zip(",
    ".filter(",
    ".filter_map(",
    ".flat_map(",
    ".chain(",
    ".take(",
    ".skip(",
    ".windows(",
    ".chunks(",
    ".cloned()",
    ".copied()",
    ".rev()",
];

const FLOAT_MARKERS: [&str; 2] = ["f64", "f32"];

const INT_MARKERS: [&str; 12] = [
    "usize", "isize", "u64", "u32", "u16", "u8", "i64", "i32", "i16", "i8",
    ".len()", ".count()",
];

/// True when `rel` (workspace-relative, forward slashes) is inside an
/// exactness-critical module.
pub fn is_critical(rel: &str) -> bool {
    CRITICAL_DIRS.iter().any(|d| rel.contains(d))
}

fn is_blessed(rel: &str, fn_name: Option<&str>) -> bool {
    let Some(name) = fn_name else {
        return false;
    };
    BLESSED
        .iter()
        .any(|(suffix, fns)| rel.ends_with(suffix) && fns.contains(&name))
}

/// `// EXACT-ALLOW: <CODE> <rationale>` on the line or within 3 lines
/// above, with the matching code and a non-empty rationale.
fn allowed(model: &SourceModel, line: usize, code: &str) -> bool {
    let lo = line.saturating_sub(3);
    (lo..=line).any(|l| {
        let Some(c) = model.comments.get(l) else {
            return false;
        };
        let Some(p) = c.find("EXACT-ALLOW:") else {
            return false;
        };
        let rest = c[p + "EXACT-ALLOW:".len()..].trim_start();
        rest.starts_with(code)
            && !rest[code.len()..].trim().is_empty()
    })
}

/// Statement slice of `joined` around byte position `pos`: back to the
/// previous `;`/`{`/`}`, forward to the next `;` (heuristic — good
/// enough to spot adapter chains and type markers). The start is
/// advanced past leading whitespace so it lands on the statement's
/// first line.
fn statement_around(joined: &str, pos: usize) -> (usize, String) {
    let bytes = joined.as_bytes();
    let mut start = pos;
    while start > 0 {
        let b = bytes[start - 1];
        if b == b';' || b == b'{' || b == b'}' {
            break;
        }
        start -= 1;
    }
    while start < pos && bytes[start].is_ascii_whitespace() {
        start += 1;
    }
    let mut end = pos;
    while end < bytes.len() {
        let b = bytes[end];
        if b == b';' || b == b'{' || b == b'}' {
            break;
        }
        end += 1;
    }
    (start, joined[start..end].to_string())
}

fn marker_class(stmt: &str) -> (bool, bool) {
    let float = FLOAT_MARKERS.iter().any(|m| stmt.contains(m));
    let int = INT_MARKERS.iter().any(|m| stmt.contains(m));
    (float, int)
}

/// All byte positions of `needle` within `hay`.
fn find_all(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = hay[from..].find(needle) {
        out.push(from + p);
        from += p + needle.len();
    }
    out
}

pub fn check(rel: &str, model: &SourceModel) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if !is_critical(rel) {
        return out;
    }
    let joined = &model.joined;

    // EXACT001 / EXACT002: iterator reductions
    let reductions: [(&str, &'static str); 6] = [
        (".sum()", EXACT001),
        (".sum::<", EXACT001),
        (".product()", EXACT001),
        (".product::<", EXACT001),
        (".fold(", EXACT002),
        (".reduce(", EXACT002),
    ];
    for (token, code) in reductions {
        for pos in find_all(joined, token) {
            let line = model.line_of(pos);
            if model.in_test[line] {
                continue;
            }
            if is_blessed(rel, model.fn_name(line)) {
                continue;
            }
            let (stmt_start, stmt) = statement_around(joined, pos);
            let before = &stmt[..pos - stmt_start];
            if !ADAPTERS.iter().any(|a| before.contains(a)) {
                // a method named sum/fold on a non-iterator receiver
                // (e.g. KBest::sum) is not a reduction site
                continue;
            }
            let (float, int) = marker_class(&stmt);
            if !float && int {
                continue;
            }
            // the annotation window anchors at the reduction token AND
            // at the statement start, so multi-line adapter chains can
            // carry the comment above the `let`
            let stmt_line = model.line_of(stmt_start);
            if allowed(model, line, code) || allowed(model, stmt_line, code) {
                continue;
            }
            let what = if code == EXACT001 {
                "iterator sum/product"
            } else {
                "fold/reduce"
            };
            out.push(Diagnostic::new(
                code,
                rel,
                line + 1,
                format!(
                    "{what} over a float (or untyped) chain reassociates \
                     under refactoring; route through a blessed kernel or \
                     annotate `// EXACT-ALLOW: {code} <why>` \
                     (token `{token}`)"
                ),
            ));
        }
    }

    // EXACT003: mul_add anywhere in a critical module
    for pos in find_all(joined, ".mul_add(") {
        let line = model.line_of(pos);
        if model.in_test[line]
            || is_blessed(rel, model.fn_name(line))
            || allowed(model, line, EXACT003)
        {
            continue;
        }
        out.push(Diagnostic::new(
            EXACT003,
            rel,
            line + 1,
            "mul_add fuses rounding (FMA) and is not bit-identical to \
             mul-then-add; forbidden in exactness-critical modules"
                .to_string(),
        ));
    }

    // EXACT004: raw accumulation loops are only allowed inside blessed
    // kernels of the linalg layer
    if rel.contains("src/linalg/") {
        for (li, lineco) in model.code.iter().enumerate() {
            if model.in_test[li] {
                continue;
            }
            let has_acc = ["+=", "-=", "*=", "/="]
                .iter()
                .any(|t| lineco.contains(t));
            if !has_acc {
                continue;
            }
            if is_blessed(rel, model.fn_name(li)) {
                continue;
            }
            if allowed(model, li, EXACT004) {
                continue;
            }
            out.push(Diagnostic::new(
                EXACT004,
                rel,
                li + 1,
                "accumulation in linalg outside a blessed kernel fn; \
                 move it into a blessed kernel (and extend the BLESSED \
                 table in a reviewed change) or annotate \
                 `// EXACT-ALLOW: EXACT004 <why>`"
                    .to_string(),
            ));
        }
    }

    out
}
