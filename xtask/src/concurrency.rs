//! Concurrency-discipline lint (LOCK001–LOCK004).
//!
//! Inventories every `unsafe` site, lock acquisition and thread spawn
//! under `rust/src/**` and requires each to carry a structured
//! annotation:
//!
//! - `unsafe` (block / fn / impl)        → `// SAFETY: <rationale>`
//! - `.lock()` / `.read()` / `.write()` /
//!   `Condvar::wait*`                    → `// LOCK-ORDER: <name> — <why>`
//! - `thread::scope` / `thread::spawn` /
//!   `scope.spawn`                       → `// THREADS: <discipline>` in
//!                                          the enclosing function
//!
//! `LOCK-ORDER` names must come from [`LOCK_ORDER`], the declared total
//! order over every lock in the tree; within one function, annotated
//! acquisitions must appear in non-decreasing rank order (`LOCK003`).
//! The check is lexical and per-function — it cannot see a lock held
//! across a call boundary — but it pins the *declared* discipline in
//! the source where a reviewer (and this lint) can diff it.
//!
//! Lock-acquisition scanning is gated to files whose code mentions a
//! sync primitive (`Mutex` / `RwLock` / `Condvar`), so `.read()` /
//! `.write()` on plain IO types elsewhere never false-positive.

use crate::diag::{Diagnostic, LOCK001, LOCK002, LOCK003, LOCK004};
use crate::source::SourceModel;

/// The declared lock order for the whole tree, outermost first: a
/// thread may only acquire a lock whose rank is >= every lock it
/// already holds. Serving layers sit above compute layers because the
/// batch worker scores *under* the registry read lock (state.rs →
/// engines → pjrt cache / linalg tile queue).
pub const LOCK_ORDER: &[(&str, &str)] = &[
    ("coordinator.registry", "state.rs deployment-registry RwLock"),
    ("coordinator.testers", "server.rs exchangeability-tester RwLock"),
    ("batcher.queue", "batcher.rs queue Mutex + Condvar"),
    ("runtime.exec_cache", "pjrt.rs executable-cache Mutex"),
    ("linalg.tile_queue", "distance.rs worker tile-iterator Mutex"),
    ("bench.result_slots", "timing.rs parallel_map output Mutex"),
    (
        "obs.deployments",
        "obs/metrics.rs per-deployment metric-block RwLock",
    ),
];

fn rank_of(name: &str) -> Option<usize> {
    LOCK_ORDER.iter().position(|(n, _)| *n == name)
}

const ACQUIRE_TOKENS: [&str; 5] =
    [".lock()", ".read()", ".write()", ".wait(", ".wait_timeout("];

const SPAWN_TOKENS: [&str; 3] = ["thread::scope(", "thread::spawn(", ".spawn("];

/// All byte positions of `needle` within `hay`.
fn find_all(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = hay[from..].find(needle) {
        out.push(from + p);
        from += p + needle.len();
    }
    out
}

/// Word-boundary occurrences of the `unsafe` keyword.
fn unsafe_sites(joined: &str) -> Vec<usize> {
    let bytes = joined.as_bytes();
    find_all(joined, "unsafe")
        .into_iter()
        .filter(|&p| {
            let before_ok = p == 0
                || !(bytes[p - 1].is_ascii_alphanumeric() || bytes[p - 1] == b'_');
            let after = p + "unsafe".len();
            let after_ok = after >= bytes.len()
                || !(bytes[after].is_ascii_alphanumeric() || bytes[after] == b'_');
            before_ok && after_ok
        })
        .collect()
}

pub fn check(rel: &str, model: &SourceModel) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let joined = &model.joined;

    // LOCK001: undocumented unsafe
    for pos in unsafe_sites(joined) {
        let line = model.line_of(pos);
        if model.in_test[line] {
            continue;
        }
        let safety = model.annotation_near(line, 3, "SAFETY:");
        if safety.is_none_or(|s| s.is_empty()) {
            out.push(Diagnostic::new(
                LOCK001,
                rel,
                line + 1,
                "`unsafe` without a structured `// SAFETY: <rationale>` \
                 comment on or directly above the site"
                    .to_string(),
            ));
        }
    }

    // lock acquisitions: only in files that use sync primitives
    let uses_sync = ["Mutex", "RwLock", "Condvar"]
        .iter()
        .any(|t| joined.contains(t));
    if uses_sync {
        // (fn index or usize::MAX, line, rank) per annotated site
        let mut acquired: Vec<(usize, usize, usize)> = Vec::new();
        for token in ACQUIRE_TOKENS {
            for pos in find_all(joined, token) {
                let line = model.line_of(pos);
                if model.in_test[line] {
                    continue;
                }
                match model.annotation_near(line, 3, "LOCK-ORDER:") {
                    None => out.push(Diagnostic::new(
                        LOCK002,
                        rel,
                        line + 1,
                        format!(
                            "lock acquisition `{token}` without a \
                             `// LOCK-ORDER: <name> — <why>` annotation"
                        ),
                    )),
                    Some(text) => {
                        let name = text.split_whitespace().next().unwrap_or("");
                        match rank_of(name) {
                            None => out.push(Diagnostic::new(
                                LOCK002,
                                rel,
                                line + 1,
                                format!(
                                    "LOCK-ORDER names unknown lock \
                                     {name:?}; declare it in \
                                     xtask::concurrency::LOCK_ORDER"
                                ),
                            )),
                            Some(rank) => {
                                let f = model
                                    .fn_of
                                    .get(line)
                                    .copied()
                                    .flatten()
                                    .unwrap_or(usize::MAX);
                                acquired.push((f, line, rank));
                            }
                        }
                    }
                }
            }
        }
        // LOCK003: within a function, ranks must be non-decreasing in
        // source order
        acquired.sort();
        for w in acquired.windows(2) {
            let (f0, _l0, r0) = w[0];
            let (f1, l1, r1) = w[1];
            if f0 == f1 && f0 != usize::MAX && r1 < r0 {
                out.push(Diagnostic::new(
                    LOCK003,
                    rel,
                    l1 + 1,
                    format!(
                        "acquisition of {:?} after {:?} violates the \
                         declared lock order (see \
                         xtask::concurrency::LOCK_ORDER)",
                        LOCK_ORDER[r1].0, LOCK_ORDER[r0].0
                    ),
                ));
            }
        }
    }

    // LOCK004: spawn sites need a THREADS discipline note in the fn
    for token in SPAWN_TOKENS {
        for pos in find_all(joined, token) {
            let line = model.line_of(pos);
            if model.in_test[line] {
                continue;
            }
            let annotated = match model.fn_of.get(line).copied().flatten() {
                Some(fi) => {
                    let f = &model.fns[fi];
                    (f.start..=f.end).any(|l| {
                        model
                            .comments
                            .get(l)
                            .is_some_and(|c| c.contains("THREADS:"))
                    })
                }
                None => model.comment_near(line, 3, "THREADS:"),
            };
            if !annotated {
                out.push(Diagnostic::new(
                    LOCK004,
                    rel,
                    line + 1,
                    format!(
                        "thread spawn `{token}` in a function without a \
                         `// THREADS: <discipline>` note"
                    ),
                ));
            }
        }
    }

    out
}
