"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Runs once via `make artifacts`; the Rust runtime then loads
`artifacts/<name>.hlo.txt` with `HloModuleProto::from_text_file` and
compiles it on the PJRT CPU client. Python is never on the request path.

Interchange format is HLO TEXT, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README).

A manifest (artifacts/manifest.json) records every artifact's entry name,
argument shapes, and output shapes so the Rust registry can bucket-match
without re-parsing HLO.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_sig(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    # Back-compat with the original Makefile single-file interface: if
    # --out is given, we treat its dirname as the artifact dir and still
    # emit the whole bucketed set plus that marker file.
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = {}
    for name, fn, example_args in model.entry_points():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "args": [shape_sig(a) for a in example_args],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        }
        print(f"  wrote {path} ({len(text)} bytes)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if args.out:
        # Marker for the Makefile dependency (model.hlo.txt): point it at
        # the canonical dist_row artifact so `make -q artifacts` works.
        smallest = f"dist_row_n{model.ROW_BUCKETS[0]}_p{model.P_BUCKETS[0]}"
        with open(args.out, "w") as f:
            f.write(open(os.path.join(out_dir, f"{smallest}.hlo.txt")).read())
    print(f"manifest: {len(manifest)} artifacts -> {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
