"""L1 Pallas kernel: fused Gaussian-KDE contribution row.

For the KDE nonconformity measure (paper §4) the prediction-phase update
needs, for a test point x, the vector

    k[i] = exp( -||x - x_i||^2 / (2 h^2) )

over all training points (the unnormalized Gaussian kernel; the measure's
1/(n_y h^p) normalization and label masking happen in the Rust
coordinator, which owns the label bookkeeping). Fusing the distance and
the exponential in one VMEM pass avoids materializing the distance row in
HBM — the classic producer-consumer fusion the paper's numpy code cannot
express.

Same tiling discipline as pairwise_dist.py: (1, p) x (TN, p) -> (1, TN)
tiles, MXU cross term, VPU exp. interpret=True for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TN = 128


def _kde_row_kernel(x_ref, b_ref, h2_ref, o_ref):
    x = x_ref[...]       # (1, p)
    b = b_ref[...]       # (TN, p)
    h2 = h2_ref[0, 0]    # scalar bandwidth^2 (prefetched whole)
    cross = jnp.dot(x, b.T, preferred_element_type=jnp.float32)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)
    d2 = jnp.maximum(x2 + b2.T - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-d2 / (2.0 * h2))


@jax.jit
def kde_row(x: jax.Array, b: jax.Array, h2: jax.Array) -> jax.Array:
    """k[j] = exp(-||x-b_j||^2 / (2 h2)) ; x:(1,p), b:(n,p), h2:(1,1)."""
    n, p = b.shape
    return pl.pallas_call(
        _kde_row_kernel,
        grid=(pl.cdiv(n, TN),),
        in_specs=[
            pl.BlockSpec((1, p), lambda j: (0, 0)),
            pl.BlockSpec((TN, p), lambda j: (j, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, TN), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=True,
    )(x, b, h2)


def _kde_matrix_kernel(a_ref, b_ref, h2_ref, o_ref):
    a = a_ref[...]
    b = b_ref[...]
    h2 = h2_ref[0, 0]
    cross = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    a2 = jnp.sum(a * a, axis=1, keepdims=True)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)
    d2 = jnp.maximum(a2 + b2.T - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-d2 / (2.0 * h2))


@functools.partial(jax.jit, static_argnames=())
def kde_matrix(a: jax.Array, b: jax.Array, h2: jax.Array) -> jax.Array:
    """K[i,j] = exp(-||a_i-b_j||^2/(2 h2)) — training-phase kernel matrix."""
    TM = 128
    m, p = a.shape
    n, _ = b.shape
    return pl.pallas_call(
        _kde_matrix_kernel,
        grid=(pl.cdiv(m, TM), pl.cdiv(n, TN)),
        in_specs=[
            pl.BlockSpec((TM, p), lambda i, j: (i, 0)),
            pl.BlockSpec((TN, p), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TM, TN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b, h2)
