"""Pure-jnp oracles for every L1 Pallas kernel.

These are the ground truth that python/tests/ (hypothesis sweeps) and the
Rust exactness tests are anchored to. No Pallas, no tiling — just the
textbook formulas.
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_sq_dists_ref(a, b):
    """D[i,j] = ||a_i - b_j||^2, direct O(m n p) broadcast."""
    diff = a[:, None, :] - b[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def dist_row_ref(x, b):
    """d[j] = ||x - b_j||^2 for x of shape (1, p)."""
    diff = x - b
    return jnp.sum(diff * diff, axis=-1)[None, :]


def kde_row_ref(x, b, h2):
    """k[j] = exp(-||x-b_j||^2 / (2 h2))."""
    return jnp.exp(-dist_row_ref(x, b) / (2.0 * h2.reshape(())))


def kde_matrix_ref(a, b, h2):
    return jnp.exp(-pairwise_sq_dists_ref(a, b) / (2.0 * h2.reshape(())))


def lssvm_train_ref(phis, ys, rho):
    """Closed-form LS-SVM (App. B.1): w* = Phi [Phi^T Phi + rho I]^-1 Y,
    C = Phi [Phi^T Phi + rho I]^-1 Phi^T.  phis: (n, q), ys: (n,)."""
    n = phis.shape[0]
    g = phis @ phis.T + rho * jnp.eye(n)
    ginv = jnp.linalg.inv(g)
    w = phis.T @ (ginv @ ys)
    c = phis.T @ ginv @ phis
    return w, c


def lssvm_update_ref(w, C, phi, y, rho, sign):
    """Lee et al. (2019) inc(+1)/dec(-1) update, dense formulas."""
    w = w.reshape(-1)
    phi = phi.reshape(-1)
    y = jnp.asarray(y).reshape(())
    rho = jnp.asarray(rho).reshape(())
    sign = jnp.asarray(sign).reshape(())
    q = w.shape[0]
    u = C @ phi - phi  # (C - I) phi
    denom = sign * (phi @ phi) + rho - sign * (phi @ C @ phi)
    w_new = w + sign * u * ((phi @ w - y) / denom)
    c_new = C + sign * jnp.outer(u, u) / denom
    return w_new.reshape(q, 1), c_new


def knn_score_update_ref(alpha_prov, delta_k, d_row, same_label):
    """Paper §3.1: alpha_i = alpha'_i - Delta_i^k + d(x_i, x) when the
    test point enters x_i's same-label k-NN set, else alpha'_i.

    alpha_prov, delta_k, d_row: (n,) f32; same_label: (n,) bool/f32 mask.
    """
    take = (d_row < delta_k) & (same_label > 0.5)
    return jnp.where(take, alpha_prov - delta_k + d_row, alpha_prov)
