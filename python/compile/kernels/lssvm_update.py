"""L1 Pallas kernel: Lee et al. (2019) exact LS-SVM rank-1 inc/dec update.

The optimized LS-SVM CP (paper §5.1, App. B.1) adds the test example to a
trained model in O(q^3) once per (test point, label) pair, then does an
O(q^2) virtual-decrement per training example. The incremental update is

    u      = (C - I_q) phi
    denom  = phi^T phi + rho - phi^T C phi          (incremental)
    w_new  = w + u (phi^T w - y) / denom
    C_new  = C + u u^T / denom

(decrement flips the signs: denom = -phi^T phi + rho + phi^T C phi,
w_new = w - ..., C_new = C - ...; we pass `sign` = +1 / -1 and fold both
cases into one kernel: denom = sign*(phi^T phi) + rho - sign*(phi^T C phi)
with the outer sign applied to the rank-1 terms).

q is small (feature-space dim; 32 after padding for the linear-kernel
p=30 experiments, up to 256 for RFF maps), so the whole state fits a
single VMEM block — the kernel is one grid step: a matvec (MXU) plus a
rank-1 outer product (VPU). This is the building block the Rust runtime
calls when PJRT backs the LS-SVM hot path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(w_ref, c_ref, phi_ref, y_ref, rho_ref, sign_ref,
                   w_out_ref, c_out_ref):
    w = w_ref[...]        # (q, 1)
    C = c_ref[...]        # (q, q)
    phi = phi_ref[...]    # (q, 1)
    y = y_ref[0, 0]
    rho = rho_ref[0, 0]
    sign = sign_ref[0, 0]  # +1 learn, -1 unlearn

    cphi = jnp.dot(C, phi, preferred_element_type=jnp.float32)   # (q, 1)
    u = cphi - phi                                               # (C - I) phi
    ptp = jnp.sum(phi * phi)
    ptcp = jnp.sum(phi * cphi)
    denom = sign * ptp + rho - sign * ptcp
    resid = jnp.sum(phi * w) - y
    w_out_ref[...] = w + sign * u * (resid / denom)
    c_out_ref[...] = C + sign * jnp.dot(
        u, u.T, preferred_element_type=jnp.float32) / denom


@jax.jit
def lssvm_update(w, C, phi, y, rho, sign):
    """One exact incremental (+1) or decremental (-1) LS-SVM update.

    w: (q,1), C: (q,q), phi: (q,1), y/rho/sign: (1,1) scalars.
    Returns (w_new, C_new).
    """
    q = w.shape[0]
    scalar = pl.BlockSpec((1, 1), lambda: (0, 0))
    return pl.pallas_call(
        _update_kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec((q, 1), lambda: (0, 0)),
            pl.BlockSpec((q, q), lambda: (0, 0)),
            pl.BlockSpec((q, 1), lambda: (0, 0)),
            scalar, scalar, scalar,
        ],
        out_specs=[
            pl.BlockSpec((q, 1), lambda: (0, 0)),
            pl.BlockSpec((q, q), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, 1), jnp.float32),
            jax.ShapeDtypeStruct((q, q), jnp.float32),
        ],
        interpret=True,
    )(w, C, phi, y, rho, sign)
