"""L1 Pallas kernel: tiled pairwise squared-Euclidean distance.

The compute hot-spot of every nearest-neighbour-family nonconformity
measure in the paper (k-NN, Simplified k-NN, KDE, k-NN regression) is
distance evaluation:

  * training phase  — the full pairwise matrix D[i,j] = ||x_i - x_j||^2
    over the training set (O(n^2 p)), used to precompute the provisional
    scores alpha'_i;
  * prediction phase — one distance row d[i] = ||x - x_i||^2 per test
    point (O(n p)), used for the O(1) incremental score updates.

TPU adaptation (DESIGN.md §Hardware-Adaptation): we express
||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b so the cross term is a rank-p
matmul that maps onto the MXU systolic array, and the norm terms are
cheap VPU broadcasts fused in-register. The grid tiles A in (TM, p)
blocks and B in (TN, p) blocks; with TM = TN = 128 and p padded to 32,
per-step VMEM is

    A tile 128x32 f32     16 KiB
    B tile 128x32 f32     16 KiB
    O tile 128x128 f32    64 KiB
    ------------------------------
                          96 KiB  « 16 MiB/core VMEM

leaving ample room for the double-buffered HBM->VMEM pipeline Pallas
emits for the two input streams.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so on this testbed the kernel runs through the Pallas
interpreter and lowers to plain HLO; real-TPU performance is estimated
analytically in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. 128 matches both the MXU systolic dimension and the lane
# width; p (feature dim) rides along whole, padded to a multiple of 8 by
# the caller (aot.py pads the experiments' p=30 / p=784 to 32 / 784).
TM = 128
TN = 128


def _pairwise_kernel(a_ref, b_ref, o_ref):
    """One (TM, TN) output tile of the squared-distance matrix.

    a_ref: (TM, p) block of A      (VMEM)
    b_ref: (TN, p) block of B      (VMEM)
    o_ref: (TM, TN) output block   (VMEM)
    """
    a = a_ref[...]
    b = b_ref[...]
    # MXU: cross term. preferred_element_type keeps f32 accumulation —
    # the paper's claim is *exact* optimization, so no bf16 here.
    cross = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    # VPU: row/col norms, fused broadcasts.
    a2 = jnp.sum(a * a, axis=1, keepdims=True)  # (TM, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)  # (TN, 1)
    d = a2 + b2.T - 2.0 * cross
    # Clamp tiny negatives from cancellation: distances are >= 0.
    o_ref[...] = jnp.maximum(d, 0.0)


def _grid(m: int, n: int) -> tuple[int, int]:
    return (pl.cdiv(m, TM), pl.cdiv(n, TN))


@functools.partial(jax.jit, static_argnames=())
def pairwise_sq_dists(a: jax.Array, b: jax.Array) -> jax.Array:
    """D[i, j] = ||a_i - b_j||^2 via the tiled Pallas kernel.

    a: (m, p) f32, b: (n, p) f32 with m, n multiples of the tile sizes
    (aot.py only lowers padded bucket shapes). Returns (m, n) f32.
    """
    m, p = a.shape
    n, _ = b.shape
    return pl.pallas_call(
        _pairwise_kernel,
        grid=_grid(m, n),
        in_specs=[
            pl.BlockSpec((TM, p), lambda i, j: (i, 0)),
            pl.BlockSpec((TN, p), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TM, TN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def _dist_row_kernel(x_ref, b_ref, o_ref):
    """One (1, TN) tile of the test-point distance row."""
    x = x_ref[...]  # (1, p)
    b = b_ref[...]  # (TN, p)
    diff_cross = jnp.dot(x, b.T, preferred_element_type=jnp.float32)  # (1, TN)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (1, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)  # (TN, 1)
    o_ref[...] = jnp.maximum(x2 + b2.T - 2.0 * diff_cross, 0.0)


@jax.jit
def dist_row(x: jax.Array, b: jax.Array) -> jax.Array:
    """d[j] = ||x - b_j||^2 for a single test point.

    x: (1, p) f32, b: (n, p) f32, n a multiple of TN. Returns (1, n).
    The per-test-point hot path of the optimized predictors.
    """
    n, p = b.shape
    return pl.pallas_call(
        _dist_row_kernel,
        grid=(pl.cdiv(n, TN),),
        in_specs=[
            pl.BlockSpec((1, p), lambda j: (0, 0)),
            pl.BlockSpec((TN, p), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, TN), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=True,
    )(x, b)
