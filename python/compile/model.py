"""L2: JAX compute graphs for the CP hot paths, composed from L1 kernels.

Each public function here is an AOT entry point: `aot.py` jit-lowers it at
a fixed shape bucket to HLO text, and the Rust runtime
(`rust/src/runtime/`) loads + executes the artifact on the PJRT CPU
client at serving time. Python never runs on the request path.

Entry points (all f32; n, p are padded bucket shapes):

  pairwise      (n,p),(n,p)                    -> (n,n)   sq. distances
  dist_row      (1,p),(n,p)                    -> (1,n)   test-point row
  dist_matrix   (m,p),(n,p)                    -> (m,n)   test-batch matrix
  kde_row       (1,p),(n,p),(1,1)              -> (1,n)   Gaussian row
  knn_update    (1,p),(n,p),(n,),(n,),(n,)     -> (1,n)   fused §3.1 update
  lssvm_update  (q,1),(q,q),(q,1),3x(1,1)      -> (q,1),(q,q)

`knn_update` is the flagship fusion: one pass computes the distance row
(Pallas), takes sqrt (the paper's measures operate on the metric d, our
kernels on d^2), and applies the paper's O(1)-per-point provisional-score
update — so a whole CP p-value's score vector is one PJRT call.

Padding contract (runtime enforces, tests verify): phantom training rows
carry `same_label = 0`, so the `knn_update` where-branch never fires for
them and phantom scores pass through; distance rows for phantom entries
are garbage and must be masked Rust-side before any k-selection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.pairwise_dist import pairwise_sq_dists, dist_row
from compile.kernels.kde_row import kde_row as _kde_row, kde_matrix
from compile.kernels.lssvm_update import lssvm_update as _lssvm_update


def pairwise(a, b):
    """Training-phase pairwise squared-distance matrix (tuple-wrapped)."""
    return (pairwise_sq_dists(a, b),)


def dist_row_fn(x, b):
    """Prediction-phase distance row for one test point."""
    return (dist_row(x, b),)


def dist_matrix_fn(a, b):
    """Prediction-phase m x n squared-distance matrix for a test batch."""
    return (pairwise_sq_dists(a, b),)


def kde_row_fn(x, b, h2):
    """Prediction-phase Gaussian kernel row (unnormalized)."""
    return (_kde_row(x, b, h2),)


def kde_matrix_fn(a, b, h2):
    """Training-phase Gaussian kernel matrix."""
    return (kde_matrix(a, b, h2),)


def knn_update(x, train, alpha_prov, delta_k, same_label):
    """Fused Simplified-k-NN score update (paper §3.1) for one test point.

    x:          (1, p)  test object
    train:      (n, p)  training objects (padded; phantoms arbitrary)
    alpha_prov: (n,)    provisional scores alpha'_i (sum of k best dists)
    delta_k:    (n,)    k-th best same-label distance per training point
    same_label: (n,)    1.0 where y_i == y-candidate, else 0.0

    Returns (1, n): the exact LOO scores alpha_i for the augmented bag
    {(x, y)} u Z \\ {(x_i, y_i)}.
    """
    d2 = dist_row(x, train)          # (1, n) squared distances (Pallas)
    d = jnp.sqrt(d2)[0]              # the measures use the metric itself
    take = (d < delta_k) & (same_label > 0.5)
    alpha = jnp.where(take, alpha_prov - delta_k + d, alpha_prov)
    return (alpha[None, :],)


def lssvm_update_fn(w, c, phi, y, rho, sign):
    """Exact LS-SVM inc(+1)/dec(-1) update (Lee et al. 2019)."""
    return _lssvm_update(w, c, phi, y, rho, sign)


# ---------------------------------------------------------------------------
# Shape buckets lowered by aot.py. Row counts are multiples of the 128
# tile; p covers the paper's two workloads (30-dim synthetic -> 32,
# 784-dim MNIST-like); q covers linear (32) and RFF (256) feature maps.
# ---------------------------------------------------------------------------

ROW_BUCKETS = (256, 1024, 4096, 16384)
P_BUCKETS = (32, 784)
Q_BUCKETS = (32, 256)
# Test-batch row buckets for dist_matrix (multiples of the 128 tile;
# mirrored by rust/src/runtime/registry.rs::M_BUCKETS).
M_BUCKETS = (128, 512)


def entry_points():
    """(name, fn, example_args) for every artifact aot.py must emit."""
    f32 = jnp.float32
    out = []
    for p in P_BUCKETS:
        for n in ROW_BUCKETS:
            xn = jax.ShapeDtypeStruct((1, p), f32)
            bn = jax.ShapeDtypeStruct((n, p), f32)
            vn = jax.ShapeDtypeStruct((n,), f32)
            s = jax.ShapeDtypeStruct((1, 1), f32)
            out.append((f"dist_row_n{n}_p{p}", dist_row_fn, (xn, bn)))
            out.append((f"kde_row_n{n}_p{p}", kde_row_fn, (xn, bn, s)))
            out.append(
                (f"knn_update_n{n}_p{p}", knn_update, (xn, bn, vn, vn, vn)))
        # Pairwise matrices only for buckets that fit memory comfortably.
        for n in (256, 1024, 4096):
            an = jax.ShapeDtypeStruct((n, p), f32)
            s = jax.ShapeDtypeStruct((1, 1), f32)
            out.append((f"pairwise_n{n}_p{p}", pairwise, (an, an)))
            out.append((f"kde_matrix_n{n}_p{p}", kde_matrix_fn, (an, an, s)))
            # Rectangular test-batch distance matrices (m test rows).
            for m in M_BUCKETS:
                am = jax.ShapeDtypeStruct((m, p), f32)
                out.append(
                    (f"dist_matrix_m{m}_n{n}_p{p}", dist_matrix_fn, (am, an)))
    for q in Q_BUCKETS:
        wq = jax.ShapeDtypeStruct((q, 1), f32)
        cq = jax.ShapeDtypeStruct((q, q), f32)
        s = jax.ShapeDtypeStruct((1, 1), f32)
        out.append((f"lssvm_update_q{q}", lssvm_update_fn,
                    (wq, cq, wq, s, s, s)))
    return out
