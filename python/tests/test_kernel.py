# pytest: Pallas kernels vs pure-jnp ref — the CORE L1 correctness signal.
#
# hypothesis sweeps shapes/values; fixed-seed cases pin the exact tile
# boundary shapes the AOT buckets use.
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.pairwise_dist import pairwise_sq_dists, dist_row, TM, TN
from compile.kernels.kde_row import kde_row, kde_matrix
from compile.kernels.lssvm_update import lssvm_update

RTOL = 1e-5
ATOL = 1e-5


def rand(shape, seed, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------- pairwise

@pytest.mark.parametrize("m,n,p", [
    (TM, TN, 32),          # single tile
    (2 * TM, 3 * TN, 32),  # multi-tile grid
    (TM, TN, 784),         # MNIST-like feature dim
])
def test_pairwise_matches_ref(m, n, p):
    a, b = rand((m, p), 1), rand((n, p), 2)
    got = pairwise_sq_dists(jnp.asarray(a), jnp.asarray(b))
    want = ref.pairwise_sq_dists_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_pairwise_self_diagonal_zero():
    a = rand((TM, 32), 3)
    d = np.asarray(pairwise_sq_dists(jnp.asarray(a), jnp.asarray(a)))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-4)
    assert (d >= 0).all(), "squared distances must be non-negative"


def test_pairwise_symmetry():
    a = rand((TM, 32), 4)
    d = np.asarray(pairwise_sq_dists(jnp.asarray(a), jnp.asarray(a)))
    np.testing.assert_allclose(d, d.T, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.sampled_from([1e-2, 1.0, 1e2]))
def test_pairwise_hypothesis(seed, scale):
    a, b = rand((TM, 32), seed, scale), rand((TN, 32), seed + 1, scale)
    got = pairwise_sq_dists(jnp.asarray(a), jnp.asarray(b))
    want = ref.pairwise_sq_dists_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale ** 2)


# ---------------------------------------------------------------- dist_row

@pytest.mark.parametrize("n,p", [(TN, 32), (4 * TN, 32), (TN, 784)])
def test_dist_row_matches_ref(n, p):
    x, b = rand((1, p), 5), rand((n, p), 6)
    got = dist_row(jnp.asarray(x), jnp.asarray(b))
    want = ref.dist_row_ref(jnp.asarray(x), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_dist_row_agrees_with_pairwise():
    x, b = rand((1, 32), 7), rand((2 * TN, 32), 8)
    row = np.asarray(dist_row(jnp.asarray(x), jnp.asarray(b)))
    # Embed x as the first row of a padded A block.
    a = np.zeros((TM, 32), np.float32)
    a[0] = x[0]
    mat = np.asarray(pairwise_sq_dists(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(row[0], mat[0], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- kde

@pytest.mark.parametrize("n,p,h2", [(TN, 32, 1.0), (2 * TN, 32, 0.5),
                                    (TN, 784, 4.0)])
def test_kde_row_matches_ref(n, p, h2):
    x, b = rand((1, p), 9), rand((n, p), 10)
    h = jnp.full((1, 1), h2, jnp.float32)
    got = kde_row(jnp.asarray(x), jnp.asarray(b), h)
    want = ref.kde_row_ref(jnp.asarray(x), jnp.asarray(b), h)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_kde_matrix_matches_ref():
    a, b = rand((TM, 32), 11), rand((2 * TN, 32), 12)
    h = jnp.full((1, 1), 2.0, jnp.float32)
    got = kde_matrix(jnp.asarray(a), jnp.asarray(b), h)
    want = ref.kde_matrix_ref(jnp.asarray(a), jnp.asarray(b), h)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_kde_row_bounds():
    x, b = rand((1, 32), 13), rand((TN, 32), 14)
    h = jnp.full((1, 1), 1.0, jnp.float32)
    k = np.asarray(kde_row(jnp.asarray(x), jnp.asarray(b), h))
    assert (k >= 0).all() and (k <= 1.0 + 1e-6).all()


# ---------------------------------------------------------------- lssvm

def _mk_state(q, n, seed, rho=1.0):
    phis = rand((n, q), seed, 0.5)
    ys = np.sign(rand((n,), seed + 1)) .astype(np.float32)
    w, c = ref.lssvm_train_ref(jnp.asarray(phis), jnp.asarray(ys), rho)
    return phis, ys, np.asarray(w).reshape(q, 1), np.asarray(c)


@pytest.mark.parametrize("q", [32, 256])
@pytest.mark.parametrize("sign", [1.0, -1.0])
def test_lssvm_update_matches_ref(q, sign):
    phis, ys, w, c = _mk_state(q, 40, 20)
    phi = phis[3].reshape(q, 1) if sign < 0 else rand((q, 1), 21, 0.5)
    y = np.float32(1.0)
    s = lambda v: jnp.full((1, 1), v, jnp.float32)
    got_w, got_c = lssvm_update(
        jnp.asarray(w), jnp.asarray(c), jnp.asarray(phi), s(y), s(1.0), s(sign))
    want_w, want_c = ref.lssvm_update_ref(
        jnp.asarray(w), jnp.asarray(c), jnp.asarray(phi), y, 1.0, sign)
    # f32 state with near-singular C at q >> n: compare against the same
    # f32 ref formula with a mixed rel/abs tolerance.
    np.testing.assert_allclose(got_w, want_w, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(got_c, want_c, rtol=1e-3, atol=1e-4)


def test_lssvm_increment_equals_retrain():
    """Exactness of Lee et al.: inc-add == closed-form retrain (f64 ref)."""
    q, n, rho = 8, 30, 1.0
    rng = np.random.default_rng(33)
    phis = rng.standard_normal((n, q))
    ys = np.sign(rng.standard_normal(n))
    # numpy f64 closed forms (the jnp ref runs in f32; numpy is the oracle)
    def train(ph, yy):
        g = ph @ ph.T + rho * np.eye(len(yy))
        gi = np.linalg.inv(g)
        return ph.T @ (gi @ yy), ph.T @ gi @ ph
    w0, c0 = train(phis[:-1], ys[:-1])
    w_inc, c_inc = ref.lssvm_update_ref(
        jnp.asarray(w0.reshape(q, 1)), jnp.asarray(c0),
        jnp.asarray(phis[-1].reshape(q, 1)), ys[-1], rho, 1.0)
    w_full, c_full = train(phis, ys)
    np.testing.assert_allclose(np.asarray(w_inc).ravel(), w_full,
                               rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(np.asarray(c_inc), c_full,
                               rtol=1e-5, atol=1e-8)


def test_lssvm_add_then_remove_roundtrip():
    q = 32
    phis, ys, w, c = _mk_state(q, 50, 22)
    phi = rand((q, 1), 23, 0.5)
    s = lambda v: jnp.full((1, 1), v, jnp.float32)
    w1, c1 = lssvm_update(jnp.asarray(w), jnp.asarray(c), jnp.asarray(phi),
                          s(-1.0), s(1.0), s(1.0))
    w2, c2 = lssvm_update(w1, c1, jnp.asarray(phi), s(-1.0), s(1.0), s(-1.0))
    np.testing.assert_allclose(w2, w, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(c2, c, rtol=1e-3, atol=1e-4)


# ------------------------------------------------------ fused knn_update

def test_knn_update_graph_matches_ref():
    from compile.model import knn_update
    n, p, k = 2 * TN, 32, 5
    rng = np.random.default_rng(55)
    train = rng.standard_normal((n, p)).astype(np.float32)
    x = rng.standard_normal((1, p)).astype(np.float32)
    labels = rng.integers(0, 2, n)
    same = (labels == 1).astype(np.float32)
    # provisional scores: true k-NN same-label sums from numpy
    d = np.sqrt(((train[:, None, :] - train[None, :, :]) ** 2).sum(-1))
    np.fill_diagonal(d, np.inf)
    alpha_prov = np.zeros(n, np.float32)
    delta_k = np.zeros(n, np.float32)
    for i in range(n):
        mask = labels == labels[i]
        mask[i] = False
        ds = np.sort(d[i, mask])[:k]
        alpha_prov[i] = ds.sum()
        delta_k[i] = ds[-1]
    (got,) = knn_update(jnp.asarray(x), jnp.asarray(train),
                        jnp.asarray(alpha_prov), jnp.asarray(delta_k),
                        jnp.asarray(same))
    drow = np.sqrt(((x - train) ** 2).sum(-1))
    want = ref.knn_score_update_ref(
        jnp.asarray(alpha_prov), jnp.asarray(delta_k),
        jnp.asarray(drow.astype(np.float32)), jnp.asarray(same))
    np.testing.assert_allclose(np.asarray(got)[0], np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_knn_update_phantom_rows_pass_through(seed):
    """Padding contract: rows with same_label=0 keep alpha' untouched."""
    from compile.model import knn_update
    n, p = TN, 32
    rng = np.random.default_rng(seed)
    train = rng.standard_normal((n, p)).astype(np.float32)
    x = rng.standard_normal((1, p)).astype(np.float32)
    alpha_prov = rng.random(n).astype(np.float32)
    delta_k = np.full(n, 1e9, np.float32)   # everything would update...
    same = np.zeros(n, np.float32)          # ...but mask forbids it
    (got,) = knn_update(jnp.asarray(x), jnp.asarray(train),
                        jnp.asarray(alpha_prov), jnp.asarray(delta_k),
                        jnp.asarray(same))
    np.testing.assert_array_equal(np.asarray(got)[0], alpha_prov)
